from repro.training.state import (
    init_state, abstract_state, state_shardings, make_bucket_plan,
)
from repro.training.step import make_train_step
