"""Train step builder.

Two distribution regimes, both one jit over the full mesh:

- ``pod_param_mode in ("sharded", "data")`` — production path. Params FSDP-sharded
  (ZeRO-3); GSPMD inserts weight all-gathers / gradient reduce-scatters. The paper's
  optimizations present: bucketed fused optimizer updates, donation, compressed MoE a2a.

- ``pod_param_mode == "replicated"`` — pure data parallelism (the paper-faithful
  Hadoop-shaped baseline: every worker holds the full model, gradients are the shuffle).
  With ``hierarchical_sync``/``compress_grads`` the gradient all-reduce is made
  *explicit* via ``jax.shard_map`` (manual over the DP axes, ``model`` stays auto):
  reduce-scatter intra-pod -> (int8) psum cross-pod -> all-gather intra-pod, with error
  feedback carried in the train state. This is where the paper's three HDFS fixes land
  on the wire, visibly in the lowered HLO.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig
from repro.core import buckets as bk
from repro.core.collectives import hierarchical_psum_1d
from repro.core.compression import compressed_psum_1d, ef_compress
from repro.models import model as mdl
from repro.models import moe as moe_mod
from repro.optim import optimizers as opt
from repro.optim.schedule import warmup_cosine
from repro.parallel.sharding import (
    batch_spec, make_rules, spec_for, use_mesh)
from repro.training.state import abstract_state, make_bucket_plan


def _opt_kind(cfg: ArchConfig, rc: RunConfig) -> str:
    if cfg.optimizer == "adafactor":
        return "adafactor"
    b = rc.bucketed_updates
    return {"adamw": "adamw_b" if b else "adamw",
            "sgdm": "sgdm_b" if b else "sgdm"}[cfg.optimizer]


def _update_biases(cfg: ArchConfig, biases, aux):
    """Aux-loss-free router-bias update from observed expert load."""
    if cfg.moe is None or cfg.moe.router != "sigmoid_bias" or not biases:
        return biases

    def upd(b, load):
        return moe_mod.update_router_bias(cfg.moe, b, load)

    new = {}
    for gk, gv in biases.items():
        a = aux.get(gk, {})
        new[gk] = {}
        for lk, bias_arr in gv.items():
            load = a.get(lk, {}).get("load")
            if load is None:
                new[gk][lk] = bias_arr
            elif bias_arr.ndim == 2:            # stacked over scan units
                new[gk][lk] = jax.vmap(upd)(bias_arr, load)
            else:
                new[gk][lk] = upd(bias_arr, load)
    return new


def _grad_metrics(grads):
    gn2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    return jnp.sqrt(gn2)


def make_train_step(cfg: ArchConfig, rc: RunConfig, mesh):
    """Returns (step_fn, state_abstract, shardings). step_fn: (state, batch)->..."""
    rules = make_rules(mesh, pod_param_mode=rc.pod_param_mode)
    plan = make_bucket_plan(cfg, rc, mesh)
    kind = _opt_kind(cfg, rc)
    explicit = (rc.pod_param_mode == "replicated" and
                (rc.hierarchical_sync or rc.compress_grads))
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def lr_at(step):
        return warmup_cosine(step, base_lr=rc.learning_rate,
                             warmup=rc.warmup_steps, total=rc.steps)

    # ------------------------------------------------------------------
    def _vg(params, biases, mb):
        return jax.value_and_grad(
            lambda pp: mdl.loss_fn(cfg, rc, pp, biases, mb), has_aux=True)(params)

    def grads_and_metrics(params, biases, batch):
        if rc.microbatch and rc.microbatch > 1:
            n = rc.microbatch

            def micro(g_acc, mb):
                (_, (mets, aux)), g = _vg(params, biases, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return g_acc, (mets, aux)

            mbatch = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)
            # accumulate in the param dtype (bf16): at 671B a fp32 accumulator is
            # a 2x-params HBM liability once the scan double-buffers the carry
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            g, (mets, aux) = jax.lax.scan(micro, g0, mbatch)
            g = jax.tree.map(lambda x: x / n, g)
            mets = jax.tree.map(lambda x: jnp.mean(x, axis=0), mets)
            aux = jax.tree.map(lambda x: jnp.sum(x, axis=0)
                               if x.ndim and x.shape[0] == n else x, aux)
            return g, mets, aux
        (_, (mets, aux)), g = _vg(params, biases, batch)
        return g, mets, aux

    # ------------------------------------------------------------------
    def optimizer_stage(state, grads, *, grads_are_buckets=False):
        lr = lr_at(state["step"])
        updates, new_opt = opt.opt_update(
            kind, state["opt"], grads, state["params"], lr=lr,
            wd=rc.weight_decay, step=state["step"], plan=plan,
            grads_are_buckets=grads_are_buckets)
        params = opt.apply_updates(state["params"], updates, plan=plan)
        return params, new_opt

    # ------------------------------------------------------------------
    if not explicit:
        def step_fn(state, batch):
            with use_mesh(mesh, rules):
                grads, mets, aux = grads_and_metrics(
                    state["params"], state["biases"], batch)
                mets = dict(mets)
                mets["grad_norm"] = _grad_metrics(grads)
                params, new_opt = optimizer_stage(state, grads)
                biases = _update_biases(cfg, state["biases"], aux)
                new_state = dict(state)
                new_state.update(params=params, opt=new_opt, biases=biases,
                                 step=state["step"] + 1)
                return new_state, mets
    else:
        # ---- explicit DP sync: shard_map manual over (pod, data) ----
        assert plan is not None, \
            "explicit sync requires bucketed_updates (and a non-adafactor opt)"
        inner = "data" if "data" in dp_axes else None
        outer = "pod" if "pod" in dp_axes else None
        codec = "int8" if rc.compress_grads else "none"

        def body(state, batch):
            with use_mesh(mesh, rules, manual_axes=frozenset(dp_axes)):
                grads, mets, aux = grads_and_metrics(
                    state["params"], state["biases"], batch)
                # expert-load stats are per-DP-shard inside the manual region;
                # globalize so the router-bias update stays replica-consistent
                if aux:
                    aux = jax.tree.map(lambda x: jax.lax.psum(x, dp_axes), aux)
                gb = bk.flatten(plan, grads)
                ef = state.get("ef")
                new_ef = []
                synced = []
                for i, g in enumerate(gb):
                    if rc.compress_grads:
                        g, e = ef_compress(g, ef[i] if ef else None)
                        new_ef.append(e)
                    if rc.hierarchical_sync:
                        g = hierarchical_psum_1d(g, inner, outer, codec=codec)
                    elif rc.compress_grads:
                        g = compressed_psum_1d(g, dp_axes)
                    else:
                        g = jax.lax.psum(g, dp_axes)
                    synced.append(g / _dp_size(mesh, dp_axes) * 1.0)
                params, new_opt = optimizer_stage(state, synced,
                                                  grads_are_buckets=True)
                biases = _update_biases(cfg, state["biases"], aux)
                mets = dict(mets)
                mets["grad_norm"] = sum(jnp.sum(jnp.square(s)) for s in synced) ** 0.5
                mets = jax.tree.map(lambda x: jax.lax.pmean(x, dp_axes), mets)
                new_state = dict(state)
                new_state.update(params=params, opt=new_opt, biases=biases,
                                 step=state["step"] + 1)
                if rc.compress_grads:
                    new_state["ef"] = new_ef
                return new_state, mets

        dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

        def step_fn(state, batch):
            st_specs = jax.tree.map(lambda _: P(), state)
            batch_specs = jax.tree.map(
                lambda x: P(dp, *([None] * (x.ndim - 1))), batch)
            from repro.core.compat import shard_map as shard_map_compat
            return shard_map_compat(
                body, mesh=mesh,
                in_specs=(st_specs, batch_specs),
                out_specs=(jax.tree.map(lambda _: P(), state), P()),
                axis_names=frozenset(dp_axes),
            )(state, batch)

    # jit with shardings + donation (the paper's direct-I/O analogue)
    st_abs = abstract_state(cfg, rc, mesh, rules)
    st_sh = jax.tree.map(lambda a: a.sharding, st_abs)

    jit_kwargs = {}
    if rc.donate_state:
        jit_kwargs["donate_argnums"] = (0,)
    fn = jax.jit(step_fn, **jit_kwargs)
    return fn, st_abs, st_sh, rules


def _dp_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return float(n)


def train_batch_specs(cfg: ArchConfig, shape, mesh, rules):
    """Shardings for the batch dict."""
    specs = mdl.input_specs(cfg, shape, mesh, rules)
    return specs
