"""Train state construction: concrete, abstract (dry-run), and sharded variants."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig
from repro.core import buckets as bk
from repro.models import model as mdl
from repro.optim import optimizers as opt
from repro.parallel.sharding import (
    abstract_params, make_rules, sharding_tree, spec_for, tree_map_schema,
    use_mesh)


def bucket_pad_multiple(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n


def make_bucket_plan(cfg: ArchConfig, rc: RunConfig, mesh) -> bk.BucketPlan | None:
    if not rc.bucketed_updates or cfg.optimizer == "adafactor":
        return None
    ps, _ = mdl.model_schema(cfg)
    abs_p = abstract_params(ps)
    return bk.make_plan(abs_p, rc.bucket_bytes, bucket_pad_multiple(mesh))


def init_state(cfg: ArchConfig, rc: RunConfig, key, mesh=None):
    """Concrete state (small configs / CPU)."""
    plan = make_bucket_plan(cfg, rc, mesh) if mesh is not None else None
    params, biases = mdl.init(cfg, key)
    o = opt.opt_init(cfg.optimizer, params,
                     bucketed=rc.bucketed_updates and cfg.optimizer != "adafactor",
                     bucket_bytes=rc.bucket_bytes,
                     pad_multiple=bucket_pad_multiple(mesh) if mesh else 1)
    state = {"params": params, "biases": biases, "opt": o,
             "step": jnp.zeros((), jnp.int32)}
    if rc.compress_grads:
        state["ef"] = (bk.zeros_like_buckets(plan) if plan is not None else
                       jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params))
    return state


def abstract_state(cfg: ArchConfig, rc: RunConfig, mesh, rules):
    """ShapeDtypeStruct state with shardings attached (dry-run path; no alloc)."""
    ps, bs = mdl.model_schema(cfg)
    with use_mesh(mesh, rules):
        aparams = abstract_params(ps)
        abiases = abstract_params(bs)
        shp = sharding_tree(ps, mesh, rules)
        shb = sharding_tree(bs, mesh, rules)
        params = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            aparams, shp)
        biases = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            abiases, shb)

        rep = NamedSharding(mesh, P())
        bucket_sh = NamedSharding(mesh, P(tuple(mesh.axis_names)))

        def opt_like(p_tree, fp32=True, factored=False):
            def mk(a):
                return jax.ShapeDtypeStruct(a.shape, jnp.float32,
                                            sharding=a.sharding)
            return jax.tree.map(mk, p_tree)

        plan = make_bucket_plan(cfg, rc, mesh)
        bucketed = plan is not None
        if cfg.optimizer == "adafactor":
            def st(path, pd):
                sp = spec_for(pd.shape, pd.dims, mesh, rules)
                full = tuple(sp) + (None,) * (len(pd.shape) - len(sp))
                if len(pd.shape) >= 2:
                    vr = jax.ShapeDtypeStruct(
                        pd.shape[:-1], jnp.float32,
                        sharding=NamedSharding(mesh, P(*full[:-1])))
                    vc = jax.ShapeDtypeStruct(
                        pd.shape[:-2] + pd.shape[-1:], jnp.float32,
                        sharding=NamedSharding(mesh, P(*(full[:-2] + (full[-1],)))))
                    return {"vr": vr, "vc": vc}
                return {"v": jax.ShapeDtypeStruct(pd.shape, jnp.float32,
                                                  sharding=NamedSharding(mesh,
                                                                         sp))}
            o = {"per": tree_map_schema(st, ps)}
        elif bucketed:
            zb = [jax.ShapeDtypeStruct((s,), jnp.float32, sharding=bucket_sh)
                  for s in plan.bucket_sizes]
            if cfg.optimizer == "adamw":
                o = {"m": zb, "v": list(zb)}
            else:
                o = {"m": zb}
        else:
            if cfg.optimizer == "adamw":
                o = {"m": opt_like(params), "v": opt_like(params)}
            else:
                o = {"m": opt_like(params)}

        state = {"params": params, "biases": biases, "opt": o,
                 "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)}
        if rc.compress_grads:
            if bucketed:
                state["ef"] = [jax.ShapeDtypeStruct((s,), jnp.float32,
                                                    sharding=bucket_sh)
                               for s in plan.bucket_sizes]
            else:
                state["ef"] = opt_like(params)
    return state


def state_shardings(state_abstract):
    return jax.tree.map(lambda a: a.sharding, state_abstract)
