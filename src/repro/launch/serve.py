"""Serving driver: batched requests through the slot-based engine."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import RunConfig, get_arch
from repro.launch.mesh import make_cpu_mesh
from repro.models import model as mdl
from repro.parallel.sharding import make_rules, use_mesh
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rc = RunConfig(arch=cfg.name, remat="none")
    mesh = make_cpu_mesh()
    rules = make_rules(mesh)
    with use_mesh(mesh, rules):
        params, biases = mdl.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, rc, params, biases, mesh, slots=args.slots,
                      max_len=args.max_len)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12)).tolist()
        eng.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    t0 = time.time()
    steps = eng.run(max_steps=args.max_len - 1)
    dt = time.time() - t0
    done = args.requests - len(eng.queue) - sum(r is not None
                                                for r in eng.active)
    print(f"[serve] {steps} decode steps, {done}/{args.requests} finished, "
          f"{dt:.2f}s ({steps/max(dt,1e-9):.1f} steps/s)")


if __name__ == "__main__":
    main()
