"""End-to-end training driver (CPU-runnable; production flags mirror the dry-run).

Exercises the full substrate: data pipeline -> train step (with the paper's
optimizations) -> metrics -> checkpointing (replicated, checksummed, async) ->
straggler monitor / failure coordinator hooks -> elastic restart.

Example (the examples/train_lm.py quickstart wraps this):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --reduced \
        --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import RunConfig, get_arch
from repro.data import Pipeline, PipelineConfig, SyntheticTokens
from repro.ft import Coordinator, StragglerMonitor
from repro.launch.mesh import make_cpu_mesh
from repro.models import model as mdl
from repro.parallel.sharding import make_rules, use_mesh
from repro.training.state import init_state
from repro.training.step import make_train_step


def train(cfg, rc: RunConfig, *, batch: int, seq: int, steps: int,
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          inject_failure_at: int = -1, mesh=None, log_every: int = 10,
          resume: bool = True):
    mesh = mesh or make_cpu_mesh()
    rules = make_rules(mesh, pod_param_mode=rc.pod_param_mode)
    step_fn, st_abs, st_sh, rules = make_train_step(cfg, rc, mesh)

    with use_mesh(mesh, rules):
        state = init_state(cfg, rc, jax.random.PRNGKey(rc.seed), mesh)

    ckpt = None
    start_step = 0
    if ckpt_dir:
        ckpt = Checkpointer(ckpt_dir, replication=2, async_io=True)
        if resume and ckpt.latest_step() is not None:
            state, manifest = ckpt.restore(state)
            start_step = manifest["step"]
            print(f"[train] resumed from step {start_step}")

    pipe = Pipeline(SyntheticTokens(cfg.vocab, rc.seed),
                    PipelineConfig(global_batch=batch, seq_len=seq,
                                   start_step=start_step)).start()
    mon = StragglerMonitor(hosts=[0])
    coord = Coordinator(hosts=[0])

    extras = {}
    if cfg.cross_attn:
        extras["cond"] = jnp.zeros((batch, cfg.cond_len, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.prefix_embeds:
        extras["prefix"] = jnp.zeros((batch, cfg.prefix_embeds, cfg.d_model),
                                     jnp.bfloat16)

    losses = []
    it = iter(pipe)
    for i in range(start_step, start_step + steps):
        step_i, tokens = next(it)
        batch_dict = {"tokens": jnp.asarray(tokens)} | extras
        t0 = time.time()
        if i == inject_failure_at:
            pipe.stop()
            raise RuntimeError(f"injected failure at step {i}")
        state, mets = step_fn(state, batch_dict)
        loss = float(mets["loss"])
        dt = time.time() - t0
        mon.record(0, dt)
        coord.heartbeat(0, time.time())
        losses.append(loss)
        if i % log_every == 0:
            print(f"[train] step={i} loss={loss:.4f} "
                  f"grad_norm={float(mets['grad_norm']):.3f} dt={dt*1e3:.0f}ms")
        if ckpt and (i + 1) % ckpt_every == 0:
            ckpt.save(i + 1, state, mesh_shape=tuple(mesh.devices.shape))
    if ckpt:
        ckpt.save(start_step + steps, state,
                  mesh_shape=tuple(mesh.devices.shape), blocking=True)
        ckpt.wait()
    pipe.stop()
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful mode (all optimizations off)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rc = RunConfig(arch=cfg.name, steps=args.steps, remat="none",
                   warmup_steps=max(args.steps // 10, 1))
    if args.baseline:
        rc = rc.paper_faithful()

    t0 = time.time()
    try:
        state, losses = train(cfg, rc, batch=args.batch, seq=args.seq,
                              steps=args.steps,
                              ckpt_dir=args.ckpt or None,
                              ckpt_every=args.ckpt_every,
                              inject_failure_at=args.inject_failure_at)
    except RuntimeError as e:
        print(f"[train] FAILURE: {e}; restarting from checkpoint...")
        state, losses = train(cfg, rc, batch=args.batch, seq=args.seq,
                              steps=args.steps,
                              ckpt_dir=args.ckpt or None,
                              ckpt_every=args.ckpt_every)
    print(f"[train] done in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
