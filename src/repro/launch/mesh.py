"""Production meshes. Functions (not module constants) so importing never touches
jax device state."""
from __future__ import annotations

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_tiny_mesh(*, multi_pod: bool = False):
    """8-device mesh for subprocess tests (XLA_FLAGS host device count = 8)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_cpu_mesh():
    """Single-device mesh with the standard axis names (smoke tests)."""
    return make_mesh((1, 1), ("data", "model"))


def pod_size(mesh) -> int:
    """Devices per pod (for cross-pod collective classification)."""
    if "pod" not in mesh.axis_names:
        return 0
    n = 1
    for a, s in zip(mesh.axis_names, mesh.devices.shape):
        if a != "pod":
            n *= s
    return n
