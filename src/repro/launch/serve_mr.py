"""MapReduce query-service driver: resident catalog + online query stream.

Loads a sky catalog once into the service (one shuffle, device-resident
tiers), then offers a paced stream of small neighbor-search / statistics
queries through the admission window and prints the qps / p50 / p99 rows.
``--qps 0`` runs a closed-loop burst (capacity); a positive value paces
arrivals at that offered load (latency under load).

    python -m repro.launch.serve_mr --n 20000 --requests 64 --qps 100
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.data import sky
from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                             neighbor_statistics_job)
from repro.serving.mr_service import MRQueryService


def query_mix(radius: float, partitioner, codec: str, tile: int):
    """The service's standing query menu: three search radii + one stats
    histogram, all ≤ the catalog partitioner's radius so every query is
    answerable from the one resident shuffle."""
    edges = np.linspace(radius / 4, radius, 4)
    return [
        neighbor_search_job(radius, partitioner=partitioner, codec=codec,
                            tile=tile),
        neighbor_search_job(radius / 2, partitioner=partitioner, codec=codec,
                            tile=tile),
        neighbor_search_job(radius / 4, partitioner=partitioner, codec=codec,
                            tile=tile),
        neighbor_statistics_job(edges / sky.ARCSEC, partitioner=partitioner,
                                codec=codec, tile=tile),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000, help="catalog rows")
    ap.add_argument("--radius", type=float, default=0.02)
    ap.add_argument("--codec", default="int16")
    ap.add_argument("--tile", type=int, default=256)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="offered load; 0 = closed-loop burst")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    args = ap.parse_args()

    xyz = sky.make_catalog(args.n, 0)
    part = ZonePartitioner(args.radius)
    svc = MRQueryService(max_batch=args.max_batch,
                         max_wait_s=args.max_wait_ms * 1e-3)
    t0 = time.perf_counter()
    cat = svc.load_catalog("sky", xyz, part, codec=args.codec,
                           tile=args.tile)
    print(f"[serve_mr] catalog: {args.n} rows -> {cat.P} partitions, "
          f"{cat.nbytes / 1e6:.1f} MB resident wire bytes, shuffled once in "
          f"{time.perf_counter() - t0:.2f}s")

    mix = query_mix(args.radius, part, args.codec, args.tile)
    # warm the jit caches so the measured stream reflects steady state
    for j in mix:
        svc.submit(j, catalog="sky")
    svc.run_pending()
    svc.request_stats.clear()
    svc.batches.clear()

    gap = 1.0 / args.qps if args.qps > 0 else 0.0
    with svc:
        t0 = time.perf_counter()
        reqs = []
        for i in range(args.requests):
            if gap:
                target = t0 + i * gap
                now = time.perf_counter()
                if target > now:
                    time.sleep(target - now)
            reqs.append(svc.submit(mix[i % len(mix)], catalog="sky"))
        outs = [r.result(timeout=600) for r in reqs]
    assert len(outs) == args.requests

    s = svc.latency_summary()
    load = f"{args.qps:.0f} qps offered" if args.qps > 0 else "closed loop"
    print(f"[serve_mr] {s['n']} queries ({load}): {s['qps']:.1f} qps served, "
          f"p50 {s['p50_ms']:.1f} ms, p99 {s['p99_ms']:.1f} ms, "
          f"queue-wait p99 {s['wait_p99_ms']:.1f} ms, "
          f"mean batch {s['mean_batch']:.1f} "
          f"({len(svc.batches)} micro-batches)")


if __name__ == "__main__":
    main()
