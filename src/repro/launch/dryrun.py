import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above must run before ANY other import (jax locks the device
# count on first init). --devices N overrides for the tiny subprocess tests.
import sys  # noqa: E402

if "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ARCHS, SHAPES, RunConfig, cell_is_applicable,
                           get_arch, get_shape)  # noqa: E402
from repro.core.amdahl import (RooflineTerms, model_flops_decode,
                               model_flops_prefill,
                               model_flops_train)  # noqa: E402
from repro.core.balance import balance_report, suggest  # noqa: E402
from repro.core.hlo_analysis import analyze_hlo, op_census  # noqa: E402
from repro.launch.mesh import (make_production_mesh, make_tiny_mesh,
                               pod_size)  # noqa: E402
from repro.models import model as mdl  # noqa: E402
from repro.parallel.sharding import make_rules, sharding_tree, use_mesh  # noqa: E402
from repro.serving.engine import make_decode_step, make_prefill_step  # noqa: E402
from repro.training.state import abstract_state  # noqa: E402
from repro.training.step import make_train_step  # noqa: E402


def rc_for_mode(cfg, shape, mode: str, overrides: dict | None = None) -> RunConfig:
    # gradient accumulation keeps train-step activation memory within HBM
    # (1M tokens/step at global batch 256 x 4k otherwise peaks several x 16G)
    micro = {"train": 16 if cfg.n_params() > 1e11 else
             (8 if cfg.moe is not None else 4)}.get(shape.kind, 0)
    base = RunConfig(arch=cfg.name, shape=shape.name, remat="full",
                     pod_param_mode="sharded", microbatch=micro)
    if mode == "baseline":
        rc = base.paper_faithful()
    elif mode == "optimized":
        # blocked_causal pays off only when attention heads shard over the model
        # axis; with the sequence-sharded fallback its dynamic block slices turn
        # into gathers (measured: granite hc1, collective term 6x WORSE)
        blocked = cfg.n_heads % 16 == 0
        rc = dataclasses.replace(
            base, bucketed_updates=True, donate_state=True,
            hierarchical_sync=True,
            compress_moe_a2a=cfg.moe is not None,
            attention_impl="blocked_causal" if blocked else "masked")
    else:
        raise ValueError(mode)
    if overrides:
        rc = dataclasses.replace(rc, **overrides)
    return rc


def _abstract_params_sharded(cfg, mesh, rules):
    ps, bs = mdl.model_schema(cfg)
    from repro.parallel.sharding import abstract_params
    with use_mesh(mesh, rules):
        ap, ab = abstract_params(ps), abstract_params(bs)
        sp, sb = sharding_tree(ps, mesh, rules), sharding_tree(bs, mesh, rules)
    mk = lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)
    return jax.tree.map(mk, ap, sp), jax.tree.map(mk, ab, sb)


def _abstract_cache_sharded(cfg, mesh, rules, batch, max_len):
    from repro.models.transformer import cache_schema
    from repro.parallel.sharding import abstract_params
    sch = cache_schema(cfg, batch, max_len)
    with use_mesh(mesh, rules):
        ac = abstract_params(sch)
        sc = sharding_tree(sch, mesh, rules)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), ac, sc)


def build_lowering(cfg, shape, mesh, rc):
    """-> (lowered, rules, model_flops)."""
    n_active = cfg.n_params_active()
    if shape.kind == "train":
        fn, st_abs, st_sh, rules = make_train_step(cfg, rc, mesh)
        batch_abs = mdl.input_specs(cfg, shape, mesh, rules)
        lowered = fn.lower(st_abs, batch_abs)
        mf = model_flops_train(n_active, shape.global_batch * shape.seq_len)
    elif shape.kind == "prefill":
        fn, rules = make_prefill_step(cfg, rc, mesh, max_len=shape.seq_len)
        p_abs, b_abs = _abstract_params_sharded(cfg, mesh, rules)
        batch_abs = mdl.input_specs(cfg, shape, mesh, rules)
        lowered = fn.lower(p_abs, b_abs, batch_abs)
        mf = model_flops_prefill(n_active, shape.global_batch * shape.seq_len)
    else:  # decode
        fn, rules = make_decode_step(cfg, rc, mesh)
        p_abs, b_abs = _abstract_params_sharded(cfg, mesh, rules)
        cache_abs = _abstract_cache_sharded(cfg, mesh, rules,
                                            shape.global_batch, shape.seq_len)
        from repro.parallel.sharding import spec_for
        from jax.sharding import NamedSharding
        with use_mesh(mesh, rules):
            tok = jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32,
                sharding=NamedSharding(mesh, spec_for((shape.global_batch, 1),
                                                      ("batch", None), mesh,
                                                      rules)))
        pos = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, jax.sharding.PartitionSpec()))
        lowered = fn.lower(p_abs, b_abs, cache_abs, tok, pos)
        mf = model_flops_decode(n_active, shape.global_batch)
    return lowered, rules, mf


def run_cell(arch: str, shape_name: str, mesh_kind: str, mode: str,
             out_dir: str, force: bool = False, overrides: dict | None = None,
             tag: str = "", moe_overrides: dict | None = None) -> dict:
    cfg = get_arch(arch)
    if moe_overrides and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_overrides))
    shape = get_shape(shape_name)
    ok, reason = cell_is_applicable(cfg, shape)
    meshname = {"single": "16x16", "multi": "2x16x16",
                "tiny": "tiny", "tinymulti": "tinymulti"}[mesh_kind]
    name = f"{arch}__{shape_name}__{meshname}__{mode}{tag}"
    path = os.path.join(out_dir, name + ".json")
    if not ok:
        rec = {"cell": name, "status": "skipped", "reason": reason}
        os.makedirs(out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[skip] {name}: {reason}")
        return rec
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            print(f"[cached] {name}")
            return rec

    if mesh_kind == "single":
        mesh = make_production_mesh(multi_pod=False)
    elif mesh_kind == "multi":
        mesh = make_production_mesh(multi_pod=True)
    elif mesh_kind == "tiny":
        mesh = make_tiny_mesh(multi_pod=False)
    else:
        mesh = make_tiny_mesh(multi_pod=True)
    n_dev = mesh.size
    rc = rc_for_mode(cfg, shape, mode, overrides)

    t0 = time.time()
    rec = {"cell": name, "arch": arch, "shape": shape_name, "mesh": meshname,
           "mode": mode, "devices": n_dev,
           "rc": {k: v for k, v in dataclasses.asdict(rc).items()
                  if not k.startswith("_")}}
    try:
        lowered, rules, mf = build_lowering(cfg, shape, mesh, rc)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        print(mem)                                    # proves it fits
        cost = compiled.cost_analysis()
        print({k: cost.get(k) for k in ("flops", "bytes accessed")})
        hlo = compiled.as_text()
        a = analyze_hlo(hlo, pod_size=pod_size(mesh))
        terms = RooflineTerms(
            flops=a.flops * n_dev,
            hbm_bytes=a.hbm_bytes * n_dev,
            coll_bytes_intra=a.coll_wire_intra * n_dev,
            coll_bytes_cross=a.coll_wire_cross * n_dev,
            chips=n_dev, model_flops=mf)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            hlo_bytes=len(hlo),
            memory={
                "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes_per_device": getattr(mem, "alias_size_in_bytes", None),
            },
            cost_analysis={k: cost.get(k) for k in ("flops", "bytes accessed")},
            analyzer=a.summary(),
            terms=terms.to_dict(),
            n_params=cfg.n_params(),
            n_params_active=cfg.n_params_active(),
            suggestion=suggest(terms),
        )
        print(balance_report(name, terms))
        print("  ->", suggest(terms))
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[ERROR] {name}: {e}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def summarize(out_dir: str):
    rows = []
    for fn in sorted(os.listdir(out_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(out_dir, fn)) as f:
            rec = json.load(f)
        rows.append(rec)
    print(f"{'cell':66s} {'status':8s} {'dom':10s} {'step_ms':>9s} "
          f"{'roofline%':>9s} {'bytes/dev':>10s}")
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r.get('cell','?'):66s} {r.get('status','?'):8s} "
                  f"{r.get('reason', r.get('error',''))[:60]}")
            continue
        t = r["terms"]
        mem = r["memory"]["argument_bytes_per_device"] or 0
        tmp = r["memory"]["temp_bytes_per_device"] or 0
        print(f"{r['cell']:66s} {'ok':8s} {t['dominant']:10s} "
              f"{t['step_time_s']*1e3:9.2f} {t['roofline_fraction']*100:8.1f}% "
              f"{(mem+tmp)/1e9:9.2f}G")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "tiny", "tinymulti", "both"])
    ap.add_argument("--mode", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--devices", default=None)   # consumed pre-import
    ap.add_argument("--summarize", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="RunConfig overrides k=v (hillclimb knobs)")
    ap.add_argument("--set-moe", action="append", default=[],
                    help="MoEConfig overrides k=v (hillclimb knobs)")
    args = ap.parse_args()

    if args.summarize:
        summarize(args.out)
        return

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        fields = {f.name: f.type for f in dataclasses.fields(RunConfig)}
        if v in ("True", "False", "true", "false"):
            v = v.lower() == "true"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        overrides[k] = v

    moe_overrides = {}
    for kv in args.set_moe:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        moe_overrides[k] = v

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_cell(arch, shape, mk, args.mode, args.out,
                               force=args.force, overrides=overrides or None,
                               tag=args.tag,
                               moe_overrides=moe_overrides or None)
                st = rec.get("status")
                n_ok += st == "ok"
                n_err += st == "error"
                n_skip += st == "skipped"
    print(f"\ndone: ok={n_ok} err={n_err} skip={n_skip}")
    if n_err:
        sys.exit(1)


if __name__ == "__main__":
    main()
