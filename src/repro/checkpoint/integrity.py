"""Chunked checksums — the paper's `io.bytes.per.checksum` analogue.

Hadoop CRC32s every 512 bytes by default; the paper found per-call overhead dominated
and raising the chunk to 4096 recovered the cost. We checksum checkpoint shards in
configurable chunks (default 1 MiB) with zlib.crc32; restore verifies and reports the
first corrupt chunk (so a partial re-fetch from a replica is possible, not a full
re-download).
"""
from __future__ import annotations

import zlib

import numpy as np

DEFAULT_CHUNK = 1 << 20


def chunk_checksums(buf: bytes | np.ndarray, chunk: int = DEFAULT_CHUNK) -> list[int]:
    if isinstance(buf, np.ndarray):
        buf = np.ascontiguousarray(buf).tobytes()
    return [zlib.crc32(buf[i:i + chunk]) & 0xFFFFFFFF
            for i in range(0, max(len(buf), 1), chunk)]


def verify(buf: bytes | np.ndarray, sums: list[int],
           chunk: int = DEFAULT_CHUNK) -> int:
    """-> -1 if intact, else index of first corrupt chunk."""
    got = chunk_checksums(buf, chunk)
    if len(got) != len(sums):
        return 0
    for i, (a, b) in enumerate(zip(got, sums)):
        if a != b:
            return i
    return -1
