"""Sharded, checksummed, replicated, async checkpointing with elastic restore.

Layout (one directory per step):

    ckpt_dir/step_000010/
        manifest.json                 # tree structure, shapes, dtypes, checksums,
                                      # replica map, mesh metadata
        host_0/<leaf-path>.npy        # primary shard files
        host_1/<leaf-path>.npy        # replica(s) (HDFS replication-factor analogue)

Design points mapped from the paper:
- replication factor R: every leaf is written to R simulated host directories;
  restore falls back across replicas on checksum failure (`dfs.replication`).
- chunked checksums with configurable chunk size (`io.bytes.per.checksum`).
- direct serialization: arrays are written with np.save straight from the device
  buffer view — no pickle staging (direct-I/O spirit).
- async: the device->host copy happens synchronously (consistency), the file I/O in a
  background thread (the paper's point that the writer should not stall the worker).

Elastic restore: the manifest stores *global* shapes; `restore` rebuilds global arrays
and re-shards them onto whatever mesh/sharding the caller provides — so a checkpoint
taken on N hosts restores onto M != N (elastic scale up/down).
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import ml_dtypes
import numpy as np

from repro.checkpoint.integrity import chunk_checksums, verify, DEFAULT_CHUNK

_EXTENDED_DTYPES = {
    "bfloat16": np.dtype(ml_dtypes.bfloat16),
    "float8_e4m3fn": np.dtype(ml_dtypes.float8_e4m3fn),
    "float8_e5m2": np.dtype(ml_dtypes.float8_e5m2),
}


def _restore_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """np.load returns void types for ml_dtypes arrays; reinterpret per manifest."""
    want = _EXTENDED_DTYPES.get(dtype_str)
    if want is None:
        want = np.dtype(dtype_str)
    if arr.dtype != want and arr.dtype.itemsize == want.itemsize:
        return arr.view(want)
    return arr


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def _unflatten_like(tree, values: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(values[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, *, replication: int = 2,
                 n_hosts: int = 4, checksum_chunk: int = DEFAULT_CHUNK,
                 async_io: bool = True, keep: int = 3):
        self.dir = directory
        self.replication = max(1, replication)
        self.n_hosts = max(self.replication, n_hosts)
        self.chunk = checksum_chunk
        self.async_io = async_io
        self.keep = keep
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, state, *, mesh_shape=None, blocking=False) -> str:
        """Snapshot `state` (pytree of arrays). Returns the checkpoint path."""
        self.wait()                      # one outstanding async save at a time
        flat = _flatten_with_paths(state)
        # synchronous device->host copy for a consistent snapshot
        host = {k: np.asarray(v) for k, v in flat.items()}

        def _write():
            d = self.step_dir(step)
            tmp = d + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "time": time.time(),
                        "mesh_shape": list(mesh_shape or []),
                        "replication": self.replication,
                        "checksum_chunk": self.chunk, "leaves": {}}
            for i, (key, arr) in enumerate(sorted(host.items())):
                replicas = [(i + r) % self.n_hosts
                            for r in range(self.replication)]
                sums = chunk_checksums(arr, self.chunk)
                rel = key.replace("/", "__") + ".npy"
                for h in replicas:
                    hd = os.path.join(tmp, f"host_{h}")
                    os.makedirs(hd, exist_ok=True)
                    np.save(os.path.join(hd, rel), arr, allow_pickle=False)
                manifest["leaves"][key] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "file": rel, "hosts": replicas, "crc32": sums,
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(d):        # re-save of the same step (restart path)
                import shutil
                shutil.rmtree(d)
            os.replace(tmp, d)           # atomic publish
            self._gc()

        if self.async_io and not blocking:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()
        return self.step_dir(step)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(self.step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("step_") and not fn.endswith(".tmp"):
                try:
                    out.append(int(fn.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.list_steps()
        return s[-1] if s else None

    def restore(self, like_state, step: int | None = None, *,
                shardings=None, failed_hosts: set[int] | None = None):
        """Rebuild `like_state`-shaped state. ``failed_hosts`` simulates dead nodes;
        restore succeeds from surviving replicas (or raises if all lost)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints in " + self.dir)
        d = self.step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        failed = failed_hosts or set()
        values = {}
        for key, meta in manifest["leaves"].items():
            arr = None
            for h in meta["hosts"]:
                if h in failed:
                    continue
                p = os.path.join(d, f"host_{h}", meta["file"])
                if not os.path.exists(p):
                    continue
                cand = np.load(p, allow_pickle=False)
                if verify(cand, meta["crc32"],
                          manifest.get("checksum_chunk", DEFAULT_CHUNK)) == -1:
                    arr = _restore_dtype(cand, meta["dtype"])
                    break
            if arr is None:
                raise IOError(f"all replicas lost/corrupt for leaf {key}")
            values[key] = arr
        sh_flat = _flatten_with_paths(shardings) if shardings is not None else {}
        out = {}
        for key, arr in values.items():
            if key in sh_flat:
                out[key] = jax.device_put(arr, sh_flat[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        return _unflatten_like(like_state, out), manifest
