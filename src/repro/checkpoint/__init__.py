from repro.checkpoint.checkpointing import Checkpointer
from repro.checkpoint.integrity import chunk_checksums, verify, DEFAULT_CHUNK
