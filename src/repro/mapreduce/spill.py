"""External shuffle spill tier: disk-backed wire-dtype segment store.

Hadoop's map tasks spill sorted partition runs to local disk and the reduce
side merges the runs per partition — that external shuffle is what lets the
paper's low-power nodes trade scarce memory for cheap sequential disk I/O.
This module is that tier for the device engine's accumulate mode: when the
streaming executor's accumulated ``MappedSplit`` wire streams exceed the
spill budget, it hands them here.

Layout — **partitioned at write time**. A flushed chunk (one or more mapped
splits) is cut into one segment file per partition RANGE ``[lo, hi)`` (the
store's ``bounds``). A range's segment carries exactly the sub-stream the
final reduce of those partitions needs:

- the payload wire rows referenced by the range: rows OWNED by a partition
  in ``[lo, hi)`` plus border rows referenced only by bucket entries
  destined there. Per-row local keys are ``key - lo`` for owned rows and
  the sentinel ``hi - lo`` for payload-only border rows (the shuffle's
  existing ``dest == P`` invalid-marker convention, applied to keys);
- the bucket entries destined to the range (``dest - lo``, source indices
  remapped into the segment's local row space).

Read-back (``read_range``) merges every committed chunk's segment for one
range into a single range-local entry stream — the ``concat_mapped`` source
offset trick on disk — which ``shuffle_reduce_device_streamed`` reduces with
``P = hi - lo``. Peak resident wire bytes are one range's, not the catalog's.

Crash safety — **finalize-rename**. Segments are staged as
``*.staged-<tag>`` and atomically ``os.replace``d to their final
``chunk<k>-range<z>.seg`` names only at commit (under the caller's commit
lock in lane mode, so a clone that loses the commit race leaves only staged
litter, swept later). A writer killed mid-stage leaves a truncated staged
file that can never be read as valid data: reads validate the byte length
against the header and raise ``ValueError`` naming the path and remainder —
the same refusal ``MemmapCatalogSplits`` applies to truncated catalogs.

Segment format: ``b"SPL1"`` magic, little-endian uint32 header length, a
JSON header (``lo``/``hi``/``d``/``rows``/``entries`` plus per-field name/
dtype/shape), then the raw field bytes concatenated in header order.

The async write path (``submit_chunk``) runs staging+commit on a
``Prefetcher`` worker thread so spill I/O hides under map compute; its
shutdown uses the prefetcher's drain-before-stop path, so a finalized chunk
handed to the writer is never dropped by a racing ``stop()``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
import queue
import shutil
import struct
import threading
import time

import numpy as np

from repro.data.pipeline import Prefetcher
from repro.mapreduce.job import MappedSplit
from repro.obs.trace import get_tracer

_MAGIC = b"SPL1"


@dataclasses.dataclass
class SpillConfig:
    """Executor-facing spill knobs.

    ``budget_bytes``: resident wire-byte budget for accumulated mapped
    streams. ``None`` or ``inf`` disables spilling (today's behavior);
    ``0`` spills every split. ``dir``: spill root (a fresh temp dir when
    None; always reclaimed on close). ``n_ranges``: read-back partition
    range count (None = sized so a range's wire bytes fit well inside the
    budget, capped at ``max_ranges``; ``"auto"`` = the cost model picks the
    fewest ranges whose read-back fits the flush watermark). ``write_fault``:
    chaos hook ``f(path)`` invoked mid-segment-write (fault injection)."""

    budget_bytes: float | None = None
    dir: str | None = None
    n_ranges: int | str | None = None
    max_ranges: int = 256
    write_fault: object = None

    @property
    def enabled(self) -> bool:
        return (self.budget_bytes is not None
                and math.isfinite(self.budget_bytes))


@dataclasses.dataclass
class SpilledChunk:
    """A staged (not yet committed) chunk: one ``*.staged-<tag>`` segment
    file per partition range. Commit renames all of them atomically-enough
    (per-file ``os.replace`` under the store lock); discard unlinks them."""

    tag: str
    paths: list                 # [(z, staged_path)] for every range z
    nbytes: int                 # field bytes across all segments
    n_splits: int               # mapped splits folded into this chunk


def mapped_to_host(m: MappedSplit) -> MappedSplit:
    """Device ``MappedSplit`` -> host numpy twin (blocks until the device
    arrays are ready; the device buffers become reclaimable once the caller
    drops its reference)."""
    return MappedSplit(
        payloads=tuple(np.asarray(p) for p in m.payloads),
        keys=np.asarray(m.keys),
        dest_eff=np.asarray(m.dest_eff),
        src=np.asarray(m.src),
        skey=None if m.skey is None else np.asarray(m.skey),
        n_rows=m.n_rows, d=m.d, nbytes_in=m.nbytes_in)


def mapped_wire_nbytes(m: MappedSplit) -> int:
    """Resident wire bytes of one mapped stream (payload + index metadata)
    — the quantity the spill budget bounds."""
    n = sum(int(p.nbytes) for p in m.payloads)
    n += int(m.keys.nbytes) + int(m.dest_eff.nbytes) + int(m.src.nbytes)
    if m.skey is not None:
        n += int(m.skey.nbytes)
    return n


def plan_bounds(weights, n_ranges: int) -> np.ndarray:
    """Byte-weighted partition-range boundaries: cut ``[0, P)`` into up to
    ``n_ranges`` contiguous ranges of near-equal total weight (per-partition
    bucket bytes/counts), so each read-back range costs about the same
    resident memory. -> strictly increasing int64 bounds, ``[0, ..., P]``."""
    w = np.clip(np.asarray(weights, np.float64), 0, None)
    P = len(w)
    Z = max(1, min(int(n_ranges), P))
    if Z == 1 or w.sum() <= 0:
        cuts = np.linspace(0, P, Z + 1).round().astype(np.int64)
    else:
        cum = np.cumsum(w)
        targets = cum[-1] * np.arange(1, Z, dtype=np.float64) / Z
        inner = np.searchsorted(cum, targets, side="left") + 1
        cuts = np.concatenate([[0], np.clip(inner, 1, P), [P]])
    bounds = np.unique(cuts).astype(np.int64)
    assert bounds[0] == 0 and bounds[-1] == P
    return bounds


class _WriterShutdown(Exception):
    """Internal: terminates the async writer's produce loop."""


class SpillStore:
    """Partition-range-bucketed spill segment store for one streaming run.

    Write side: ``stage_chunk`` (synchronous; lanes call it from their own
    thread) + ``commit_chunk`` / ``discard_chunk`` (the lane-safe
    finalize-rename), or ``submit_chunk`` + ``wait_writes`` (the sequential
    executor's async double-buffered path). Read side: ``read_range(z)``
    merges every committed chunk's segment for range ``z``. ``close()``
    shuts the writer down via the prefetcher drain path and reclaims the
    spill directory — call it success or failure (the executor wraps the
    run in try/finally).
    """

    def __init__(self, root: str, P: int, *, write_fault=None,
                 on_written=None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.P = int(P)
        self.write_fault = write_fault
        self.on_written = on_written      # f(SpilledChunk) after async commit
        self._bounds: np.ndarray | None = None
        self._lock = threading.Lock()
        self._n_committed = 0
        self._n_tagged = 0
        self.bytes_written = 0
        self.write_wall_s = 0.0
        self.max_chunk_bytes = 0
        self._wq: queue.Queue = queue.Queue()
        self._closed = threading.Event()
        self._write_error: BaseException | None = None
        self._writer: Prefetcher | None = None

    # -- bounds ------------------------------------------------------------

    def set_bounds(self, bounds) -> None:
        b = np.asarray(bounds, np.int64)
        if (len(b) < 2 or b[0] != 0 or b[-1] != self.P
                or not (np.diff(b) > 0).all()):
            raise ValueError(f"invalid range bounds {b.tolist()!r} for "
                             f"P={self.P}: need strictly increasing "
                             f"[0, ..., P]")
        if self._bounds is not None:
            raise RuntimeError("range bounds already set — segments on disk "
                               "are partitioned by them")
        self._bounds = b

    @property
    def bounds(self) -> np.ndarray:
        if self._bounds is None:
            raise RuntimeError("SpillStore bounds not set — call "
                               "set_bounds/plan_bounds before staging")
        return self._bounds

    @property
    def n_ranges(self) -> int:
        return len(self.bounds) - 1

    @property
    def n_chunks(self) -> int:
        return self._n_committed

    def next_tag(self) -> str:
        with self._lock:
            t = self._n_tagged
            self._n_tagged += 1
        return f"t{t}"

    # -- write side --------------------------------------------------------

    def _seg_path(self, cid: int, z: int) -> str:
        return os.path.join(self.root, f"chunk{cid:05d}-range{z:04d}.seg")

    def stage_chunk(self, recs, tag: str) -> SpilledChunk:
        """Cut host mapped splits ``recs`` into one staged segment per
        partition range. Every range gets a segment (possibly zero-row) so
        read-back always finds dtype/shape metadata. Crash mid-call leaves
        only ``*.staged-<tag>`` litter — nothing committed."""
        recs = list(recs)
        assert recs, "stage_chunk needs at least one mapped split"
        bounds = self.bounds
        paths, nbytes = [], 0
        # one spill-write span per staged chunk, on whichever thread writes
        # (a lane staging its own split, or the store's async writer)
        with get_tracer().span("spill-write", cat="io", tag=tag,
                               n_splits=len(recs)):
            for z in range(len(bounds) - 1):
                lo, hi = int(bounds[z]), int(bounds[z + 1])
                path = self._seg_path(0, z) + f".staged-{tag}"
                nbytes += _write_segment(path, recs, lo, hi,
                                         write_fault=self.write_fault)
                paths.append((z, path))
        return SpilledChunk(tag=tag, paths=paths, nbytes=nbytes,
                            n_splits=len(recs))

    def commit_chunk(self, chunk: SpilledChunk) -> int:
        """Finalize-rename a staged chunk under the store lock (lane commit
        runs this inside the pool's commit section: first finisher renames,
        the loser's staged files stay staged and are swept). -> chunk id."""
        with self._lock:
            cid = self._n_committed
            for z, staged in chunk.paths:
                os.replace(staged, self._seg_path(cid, z))
            self._n_committed += 1
            self.bytes_written += chunk.nbytes
            self.max_chunk_bytes = max(self.max_chunk_bytes, chunk.nbytes)
        return cid

    def discard_chunk(self, chunk: SpilledChunk) -> None:
        for _, staged in chunk.paths:
            with contextlib.suppress(OSError):
                os.unlink(staged)

    def sweep_staged(self) -> int:
        """Unlink every leftover staged segment (cancelled clones, faulted
        writers). -> count removed."""
        n = 0
        for name in os.listdir(self.root):
            if ".staged-" in name:
                with contextlib.suppress(OSError):
                    os.unlink(os.path.join(self.root, name))
                    n += 1
        return n

    # -- async writer (sequential executor's double buffer) ----------------

    def submit_chunk(self, recs) -> None:
        """Queue host mapped splits for background stage+commit. At most
        one submission should be in flight (callers ``wait_writes`` before
        the next) — that is what bounds peak resident bytes."""
        if self._writer is None:
            self._writer = Prefetcher(self._write_next, depth=8).start()
        self._wq.put(list(recs))

    def _write_next(self, k: int):
        while True:
            try:
                req = self._wq.get(timeout=0.1)
                break
            except queue.Empty:
                if self._closed.is_set():
                    raise _WriterShutdown()
        if req is None:                    # close() sentinel
            self._wq.task_done()
            raise _WriterShutdown()
        t0 = time.perf_counter()
        try:
            chunk = self.stage_chunk(req, f"async{k}")
            self.commit_chunk(chunk)
            if self.on_written is not None:
                self.on_written(chunk)
            return chunk
        except BaseException as e:         # surfaced by wait_writes
            self._write_error = e
            return None
        finally:
            self.write_wall_s += time.perf_counter() - t0
            self._wq.task_done()

    def wait_writes(self) -> None:
        """Block until every submitted chunk is staged+committed; re-raise
        the first writer error (the chunk that failed stays uncommitted)."""
        self._wq.join()
        if self._write_error is not None:
            e, self._write_error = self._write_error, None
            raise e

    # -- read side ---------------------------------------------------------

    def range_bounds(self, z: int) -> tuple:
        b = self.bounds
        return int(b[z]), int(b[z + 1])

    def range_segment_paths(self, z: int) -> list:
        return [self._seg_path(cid, z) for cid in range(self._n_committed)]

    def read_range(self, z: int) -> dict:
        """Merge every committed chunk's segment for range ``z`` into one
        range-local entry stream (source indices offset per segment, the
        ``concat_mapped`` trick). Validates each segment's byte length and
        refuses truncated files. -> record dict with ``lo``/``hi``, host
        wire ``payloads``, local ``keys``/``dest_eff``/``src``, ``skey``,
        ``d`` and ``n_rows``."""
        lo, hi = self.range_bounds(z)
        if self._n_committed == 0:
            raise ValueError("read_range on a store with no committed "
                             "chunks")
        segs = [_read_segment(p, expect_lo=lo, expect_hi=hi)
                for p in self.range_segment_paths(z)]
        pnames = [f[0] for f in segs[0]["fields"] if f[0].startswith("p")]
        has_skey = any(f[0] == "skey" for f in segs[0]["fields"])
        pls = [[] for _ in pnames]
        keys, dest, src, skeys = [], [], [], []
        row_off = 0
        for s in segs:
            for i, name in enumerate(pnames):
                pls[i].append(s["data"][name])
            keys.append(s["data"]["keys"])
            dest.append(s["data"]["dest"])
            src.append(s["data"]["src"] + np.int32(row_off))
            if has_skey:
                skeys.append(s["data"]["skey"])
            row_off += int(s["rows"])
        return {
            "lo": lo, "hi": hi,
            "payloads": tuple(np.concatenate(p) for p in pls),
            "keys": np.concatenate(keys),
            "dest_eff": np.concatenate(dest),
            "src": np.concatenate(src),
            "skey": np.concatenate(skeys) if has_skey else None,
            "d": int(segs[0]["d"]),
            "n_rows": row_off,
        }

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Drain-stop the async writer and reclaim the spill directory.
        Safe to call multiple times and after failures."""
        try:
            if self._writer is not None:
                with contextlib.suppress(BaseException):
                    self._wq.join()
                self._closed.set()
                self._wq.put(None)
                self._writer.stop(drain=True)
                self._writer = None
        finally:
            shutil.rmtree(self.root, ignore_errors=True)


# ---------------------------------------------------------------------------
# Segment file I/O
# ---------------------------------------------------------------------------

def _range_selections(recs, lo: int, hi: int):
    """Per-rec selection metadata for one partition range: selected payload
    row indices, range-local keys (``hi-lo`` marks payload-only border
    rows), and the range's bucket entries remapped into the chunk's local
    row space (offsets accumulate across recs)."""
    span = hi - lo
    outs, row_off = [], 0
    for m in recs:
        keys, dest, src = m.keys, m.dest_eff, m.src
        own = (keys >= lo) & (keys < hi)
        ent = (dest >= lo) & (dest < hi)       # dest == P never lands here
        need = own.copy()
        if ent.any():
            need[src[ent]] = True
        sel = np.flatnonzero(need)
        remap = np.full(keys.shape[0], -1, np.int32)
        remap[sel] = np.arange(len(sel), dtype=np.int32)
        keys_local = np.where(own[sel], keys[sel] - lo,
                              span).astype(np.int32)
        dest_local = (dest[ent] - lo).astype(np.int32)
        src_local = (remap[src[ent]] + row_off).astype(np.int32)
        outs.append((sel, keys_local, dest_local, src_local))
        row_off += len(sel)
    return outs


def _write_segment(path: str, recs, lo: int, hi: int,
                   write_fault=None) -> int:
    """Write one range segment for a chunk of mapped splits. Returns field
    bytes written. ``write_fault(path)`` fires mid-write (after the header
    and payload, before the index fields) so injected faults leave a
    length-invalid file, exactly what a real crash leaves."""
    sels = _range_selections(recs, lo, hi)
    n_rows = sum(len(s[0]) for s in sels)
    n_entries = sum(len(s[2]) for s in sels)
    p0 = recs[0].payloads
    has_skey = recs[0].skey is not None
    fields = [(f"p{i}", np.dtype(p.dtype).str,
               (n_rows,) + tuple(p.shape[1:])) for i, p in enumerate(p0)]
    fields += [("keys", "<i4", (n_rows,)), ("dest", "<i4", (n_entries,)),
               ("src", "<i4", (n_entries,))]
    if has_skey:
        fields.append(("skey", np.dtype(recs[0].skey.dtype).str, (n_rows,)))
    header = {"lo": int(lo), "hi": int(hi), "d": int(recs[0].d),
              "rows": int(n_rows), "entries": int(n_entries),
              "fields": [[n, dt, list(sh)] for n, dt, sh in fields]}
    hb = json.dumps(header).encode()
    nbytes = 0
    with open(path, "wb") as f:
        f.write(_MAGIC + struct.pack("<I", len(hb)) + hb)

        def emit(arr):
            nonlocal nbytes
            a = np.ascontiguousarray(arr)
            f.write(a.tobytes())
            nbytes += a.nbytes

        for i in range(len(p0)):
            for m, (sel, _, _, _) in zip(recs, sels):
                emit(np.asarray(m.payloads[i])[sel])
        for _, kl, _, _ in sels:
            emit(kl)
        if write_fault is not None:
            write_fault(path)
        for _, _, dl, _ in sels:
            emit(dl)
        for _, _, _, sl in sels:
            emit(sl)
        if has_skey:
            for m, (sel, _, _, _) in zip(recs, sels):
                emit(np.asarray(m.skey)[sel])
    return nbytes


def _read_segment(path: str, expect_lo: int | None = None,
                  expect_hi: int | None = None) -> dict:
    """Parse + validate one segment file. The byte length must match the
    header exactly; a crash-truncated segment raises ``ValueError`` naming
    the path and remainder instead of silently reading short."""
    with open(path, "rb") as f:
        buf = f.read()
    size = len(buf)
    if size < 8 or buf[:4] != _MAGIC:
        raise ValueError(f"spilled segment {path!r}: missing/invalid magic "
                         f"({size} bytes) — truncated or corrupt")
    (hlen,) = struct.unpack("<I", buf[4:8])
    if size < 8 + hlen:
        raise ValueError(f"spilled segment {path!r}: header truncated "
                         f"({size} bytes, header claims {hlen})")
    header = json.loads(buf[8:8 + hlen])
    fields = header["fields"]
    expected = sum(int(np.dtype(dt).itemsize) * int(np.prod(sh))
                   for _, dt, sh in fields)
    rem = size - 8 - hlen - expected
    if rem != 0:
        raise ValueError(
            f"spilled segment {path!r} is {size} bytes, expected "
            f"{8 + hlen + expected} ({rem:+d} byte remainder) — truncated "
            f"or corrupt; refusing to silently read a shorter stream")
    if expect_lo is not None and (header["lo"] != expect_lo
                                  or header["hi"] != expect_hi):
        raise ValueError(f"spilled segment {path!r} covers partitions "
                         f"[{header['lo']}, {header['hi']}), expected "
                         f"[{expect_lo}, {expect_hi})")
    data, off = {}, 8 + hlen
    for name, dt, sh in fields:
        nb = int(np.dtype(dt).itemsize) * int(np.prod(sh))
        data[name] = np.frombuffer(
            buf[off:off + nb], dtype=np.dtype(dt)).reshape(sh)
        off += nb
    return {"lo": header["lo"], "hi": header["hi"], "d": header["d"],
            "rows": header["rows"], "entries": header["entries"],
            "fields": fields, "data": data}
