"""Composable MapReduce jobs: one engine, pluggable stages.

The paper's wins (buffered writes, LZO shuffle compression, direct I/O) all
swap a *stage* of Hadoop's fixed map -> shuffle -> reduce pipeline without
touching job logic. This module makes that the API:

- ``Partitioner``   (map): key assignment + border-replication policy,
- ``ShuffleCodec``  (shuffle): wire format, by registry name (``codecs.py``),
- ``Reducer``       (reduce): per-partition kernel + host-side finalize,

composed into a ``MapReduceJob`` and executed by one engine that handles
capacity padding, mesh sharding (``shard_map`` over the ``data`` axis), and
multi-job batching (jobs sharing a partitioner/codec do ONE map+shuffle and a
single fused reduce pass). Every run emits ``StageStats`` — per-stage bytes,
FLOPs, and wall time — which ``StageStats.roofline()`` turns into the paper's
Amdahl-number analysis for *any* job, not just the two hard-coded apps.

    job = MapReduceJob("search", ZonePartitioner(radius), PairCountReducer(r),
                       codec="int16")
    result = run_job(job, xyz, mesh=mesh)
    result.output, result.stats.to_dict()
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import shard_map as _shard_map_compat
from repro.mapreduce.codecs import ShuffleCodec, get_codec
from repro.mapreduce.instrumentation import StageStats


def _round_up(x: int, m: int) -> int:
    return max(m, ((x + m - 1) // m) * m)


def _pad_rows(x: np.ndarray, n: int, fill: float) -> np.ndarray:
    out = np.full((n, x.shape[1]), fill, x.dtype)
    out[:len(x)] = x
    return out


def _data_axis_size(mesh) -> int:
    if mesh is None or "data" not in mesh.axis_names:
        return 1
    return int(mesh.shape["data"])


# ---------------------------------------------------------------------------
# Pluggable stages
# ---------------------------------------------------------------------------

class Partitioner:
    """Map stage: assigns each item a partition key, and optionally replicates
    items into neighboring partitions (the paper's mappers "copy objects
    within a certain region around each block")."""

    def n_partitions(self, items: np.ndarray) -> int:
        raise NotImplementedError

    def assign(self, items: np.ndarray) -> np.ndarray:
        """-> [n] int32 owning-partition ids."""
        raise NotImplementedError

    def replicas(self, items: np.ndarray, keys: np.ndarray, n_parts: int):
        """Yield (dest_partition, item_index_array) border copies. Default:
        none (self-contained partitions, e.g. hash partitioning)."""
        return ()


@dataclasses.dataclass
class HashPartitioner(Partitioner):
    """Key mod n_parts on the first column — Hadoop's default partitioner."""

    n_parts: int

    def n_partitions(self, items):
        return self.n_parts

    def assign(self, items):
        key = items[:, 0] if items.ndim > 1 else items
        return (np.asarray(key).astype(np.int64) % self.n_parts
                ).astype(np.int32)


class Reducer:
    """Reduce stage: a per-partition kernel (traced under ``lax.map`` /
    ``shard_map``, so fixed output shape) plus a host-side ``finalize``.
    Partition results are combined by summation (psum across the mesh)."""

    pad_value: float = 0.0   # fill for capacity padding; pick one kernels ignore

    def per_partition(self, owned_p, bucket_p):
        """[C1, d], [C2, d] -> fixed-shape array, summed over partitions."""
        raise NotImplementedError

    def finalize(self, total, sd: "ShuffledData"):
        """Host-side post-combine (dedup corrections, differencing, ...)."""
        return np.asarray(total)

    def flops(self, sd: "ShuffledData") -> float:
        """Estimated reduce-stage FLOPs, for StageStats/Amdahl accounting."""
        return 0.0


@dataclasses.dataclass
class ShuffledData:
    """Post-shuffle state: fixed-capacity padded per-partition arrays."""

    owned: np.ndarray          # [P, C1, d] (pad_value-padded)
    bucket: np.ndarray         # [P, C2, d] owned + replicas (pad_value-padded)
    n_owned: np.ndarray        # [P] int32 real counts
    n_bucket: np.ndarray       # [P] int32 real counts


@dataclasses.dataclass
class MapReduceJob:
    """A named composition of the three pluggable stages."""

    name: str
    partitioner: Partitioner
    reducer: Reducer
    codec: str | ShuffleCodec = "identity"
    tile: int = 256            # capacity quantum (the paper's block size)


@dataclasses.dataclass
class JobResult:
    output: object
    stats: StageStats


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def shuffle_stage(items, partitioner: Partitioner, codec="identity", *,
                  tile: int = 256, pad_partitions_to: int = 1,
                  pad_value: float = 0.0,
                  stats: StageStats | None = None) -> ShuffledData:
    """Map (assign + replicate) then shuffle (codec wire trip, pad, stack).

    The codec round-trips the payload exactly as the wire would see it;
    ``stats.shuffle_wire_bytes`` counts codec bytes for every point that
    lands in a bucket (owned + border copies), matching the paper's
    "bytes that crossed the shuffle" accounting.
    """
    codec = get_codec(codec)
    items = np.asarray(items)
    if items.ndim == 1:
        items = items[:, None]
    stats = stats if stats is not None else StageStats()

    t0 = time.perf_counter()
    P = int(partitioner.n_partitions(items))
    keys = np.asarray(partitioner.assign(items))
    owned_idx = [np.flatnonzero(keys == k) for k in range(P)]
    bucket_idx = [[idx] for idx in owned_idx]
    for dest, idx in partitioner.replicas(items, keys, P):
        bucket_idx[dest].append(np.asarray(idx))
    stats.map_wall_s = time.perf_counter() - t0
    stats.map_bytes = items.nbytes

    t0 = time.perf_counter()
    decoded = codec.roundtrip(items).astype(np.float32)
    P_pad = _round_up(P, pad_partitions_to)
    d = items.shape[1]
    owned_lists = [decoded[i] for i in owned_idx]
    bucket_lists = [decoded[np.concatenate(parts)] for parts in bucket_idx]
    empty = np.zeros((0, d), np.float32)
    owned_lists += [empty] * (P_pad - P)
    bucket_lists += [empty] * (P_pad - P)
    C1 = _round_up(max(len(o) for o in owned_lists), tile)
    C2 = _round_up(max(len(b) for b in bucket_lists), tile)
    sd = ShuffledData(
        owned=np.stack([_pad_rows(o, C1, pad_value) for o in owned_lists]),
        bucket=np.stack([_pad_rows(b, C2, pad_value) for b in bucket_lists]),
        n_owned=np.array([len(o) for o in owned_lists], np.int32),
        n_bucket=np.array([len(b) for b in bucket_lists], np.int32),
    )
    n_shuffled = int(sd.n_bucket.sum())
    stats.shuffle_wall_s = time.perf_counter() - t0
    stats.shuffle_wire_bytes = codec.nbytes(n_shuffled * d)
    stats.shuffle_raw_bytes = 4 * n_shuffled * d
    stats.n_items = len(items)
    stats.n_partitions = P_pad
    stats.codec = codec.name
    return sd


def reduce_stage(reducers, sd: ShuffledData, mesh=None):
    """Run every reducer's per-partition kernel in ONE pass over the buckets
    (multi-job batching), summing over partitions — sharded over the mesh's
    ``data`` axis with a psum combine when a mesh is given. -> tuple of
    per-reducer totals."""
    owned, bucket = jnp.asarray(sd.owned), jnp.asarray(sd.bucket)

    def per_part(o, b):
        return tuple(r.per_partition(o, b) for r in reducers)

    if _data_axis_size(mesh) == 1:
        outs = jax.lax.map(lambda ab: per_part(ab[0], ab[1]), (owned, bucket))
        return tuple(jnp.sum(o, axis=0) for o in outs)

    from jax.sharding import PartitionSpec as P

    def body(o, b):
        r = jax.lax.map(lambda ab: per_part(ab[0], ab[1]), (o, b))
        return tuple(jax.lax.psum(jnp.sum(x, axis=0), "data") for x in r)

    D = _data_axis_size(mesh)
    assert owned.shape[0] % D == 0, (owned.shape, dict(mesh.shape))
    spec = P("data", None, None)
    return _shard_map_compat(
        body, mesh=mesh, in_specs=(spec, spec),
        out_specs=tuple(P() for _ in reducers),
        axis_names=frozenset({"data"}))(owned, bucket)


def run_jobs(jobs, items, *, mesh=None) -> list[JobResult]:
    """Execute several jobs that share partitioner/codec/tile through ONE
    map+shuffle and one fused reduce pass (e.g. Neighbor Searching and
    Neighbor Statistics over the same catalog cost a single data pass).
    -> one JobResult per job, sharing a single StageStats."""
    if not jobs:
        return []
    j0 = jobs[0]
    c0 = get_codec(j0.codec)
    for j in jobs[1:]:
        diffs = [k for k, a, b in [
            ("partitioner", j.partitioner, j0.partitioner),
            ("codec", get_codec(j.codec).name, c0.name),
            ("tile", j.tile, j0.tile),
            ("pad_value", j.reducer.pad_value, j0.reducer.pad_value),
        ] if a != b]
        if diffs:
            raise ValueError(
                f"batched jobs must share one shuffle: {j.name!r} differs "
                f"from {j0.name!r} in {', '.join(diffs)}")
    stats = StageStats(job="+".join(j.name for j in jobs))
    sd = shuffle_stage(items, j0.partitioner, c0, tile=j0.tile,
                       pad_partitions_to=_data_axis_size(mesh),
                       pad_value=j0.reducer.pad_value, stats=stats)
    t0 = time.perf_counter()
    totals = jax.block_until_ready(
        reduce_stage([j.reducer for j in jobs], sd, mesh))
    stats.reduce_wall_s = time.perf_counter() - t0
    stats.reduce_bytes = sd.owned.nbytes + sd.bucket.nbytes
    stats.reduce_flops = float(sum(j.reducer.flops(sd) for j in jobs))
    return [JobResult(j.reducer.finalize(t, sd), stats)
            for j, t in zip(jobs, totals)]


def run_job(job: MapReduceJob, items, *, mesh=None) -> JobResult:
    """Execute one job end-to-end. -> JobResult(output, stats)."""
    return run_jobs([job], items, mesh=mesh)[0]
