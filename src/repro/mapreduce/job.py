"""Composable MapReduce jobs: two engines, pluggable stages.

The paper's wins (buffered writes, LZO shuffle compression, direct I/O) all
swap a *stage* of Hadoop's fixed map -> shuffle -> reduce pipeline without
touching job logic. This module makes that the API:

- ``Partitioner``   (map): key assignment + border-replication policy,
- ``ShuffleCodec``  (shuffle): wire format, by registry name (``codecs.py``),
- ``Reducer``       (reduce): per-partition kernel + host-side finalize,

composed into a ``MapReduceJob`` and executed by one of two engines:

- ``engine="device"`` (default off-mesh): the hot path. Partition
  assignment, border replication, argsort-based bucketing, and capacity
  padding are vectorized array ops; the payload crosses the shuffle in the
  codec's *wire dtype* (int16/int8) and is decoded on-device at the start
  of the reduce, so shuffle traffic shrinks with the codec ratio.
  Partitions are grouped into size tiers (``plan_tiers``) so one skewed
  partition doesn't inflate every partition's capacity padding, and each
  tier reduces through batched masked kernels (``pair_count_masked`` & co.:
  Pallas partition-grid kernels on TPU, the z-banded blocked engine
  elsewhere) instead of a sequential ``lax.map``. Under a ``data``-axis
  mesh the tier arrays are padded so every tier's partition count divides
  the axis size, each shard reduces its own rows, and tier partials
  combine with a ``psum`` (``_reduce_tier_sharded``) — the fast path and
  the scalable path are no longer mutually exclusive.
- ``engine="host"``: the original numpy shuffle + per-partition ``lax.map``
  reduce. Kept as the oracle-parity path (also under a mesh: the device
  engine's sharded results are bit-identical for exact codecs).

Both engines handle multi-job batching (jobs sharing a partitioner/codec do
ONE map+shuffle and a single fused reduce pass) and emit ``StageStats`` —
per-stage bytes, FLOPs, and wall time (fenced with ``block_until_ready``) —
which ``StageStats.roofline()`` turns into the paper's Amdahl-number
analysis for *any* job, not just the two hard-coded apps.

    job = MapReduceJob("search", ZonePartitioner(radius), PairCountReducer(r),
                       codec="int16")
    result = run_job(job, xyz)                     # device engine
    result = run_job(job, xyz, mesh=mesh)          # device engine, sharded
    result.output, result.stats.to_dict()
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import shard_map as _shard_map_compat
from repro.mapreduce.codecs import ShuffleCodec, get_codec
from repro.mapreduce.instrumentation import StageStats
from repro.obs.energy import get_meter
from repro.obs.trace import get_tracer


def _round_up(x: int, m: int) -> int:
    return max(m, ((x + m - 1) // m) * m)


def _pad_rows(x: np.ndarray, n: int, fill: float) -> np.ndarray:
    out = np.full((n, x.shape[1]), fill, x.dtype)
    out[:len(x)] = x
    return out


def _data_axis_size(mesh) -> int:
    if mesh is None or "data" not in mesh.axis_names:
        return 1
    return int(mesh.shape["data"])


# ---------------------------------------------------------------------------
# Pluggable stages
# ---------------------------------------------------------------------------

class Partitioner:
    """Map stage: assigns each item a partition key, and optionally replicates
    items into neighboring partitions (the paper's mappers "copy objects
    within a certain region around each block")."""

    def n_partitions(self, items: np.ndarray) -> int:
        raise NotImplementedError

    def assign(self, items: np.ndarray) -> np.ndarray:
        """-> [n] int32 owning-partition ids."""
        raise NotImplementedError

    def replicas(self, items: np.ndarray, keys: np.ndarray, n_parts: int):
        """Yield (dest_partition, item_index_array) border copies. Default:
        none (self-contained partitions, e.g. hash partitioning)."""
        return ()

    # -- device (jax) hooks: the engine="device" map stage -----------------

    def assign_device(self, items):
        """jnp version of ``assign`` ([n, d] device array -> [n] int32).
        Default: round-trips through the host ``assign``."""
        return jnp.asarray(self.assign(np.asarray(items)), jnp.int32)

    def sort_key_device(self, items):
        """Optional [n] secondary sort key: rows within a partition land in
        this order, which tightens the per-tile ranges the z-banded blocked
        reduce prunes on (``ZonePartitioner`` returns z). Order never
        affects results — partition reductions are commutative sums — so
        ``None`` (arrival order) is always correct."""
        return None

    def bucket_entries_device(self, items, keys, n_parts: int):
        """-> (dest [m] int32, src [m] int32, valid [m] bool): every
        (partition, item) bucket entry — owned points plus border copies —
        with a static entry count ``m`` so the whole stream can be bucketed
        by one argsort. Default: owned entries device-side, replicas (if
        any) via the host ``replicas`` hook."""
        n = keys.shape[0]
        idx = jnp.arange(n, dtype=jnp.int32)
        reps = list(self.replicas(np.asarray(items), np.asarray(keys),
                                  n_parts))
        if not reps:
            return keys, idx, jnp.ones((n,), bool)
        r_dest = np.concatenate(
            [np.full(len(i), d, np.int32) for d, i in reps] or
            [np.zeros(0, np.int32)])
        r_src = np.concatenate([np.asarray(i, np.int32) for _, i in reps])
        dest = jnp.concatenate([keys, jnp.asarray(r_dest)])
        src = jnp.concatenate([idx, jnp.asarray(r_src)])
        return dest, src, jnp.ones((dest.shape[0],), bool)


@dataclasses.dataclass(frozen=True)
class HashPartitioner(Partitioner):
    """Key mod n_parts on the first column — Hadoop's default partitioner."""

    n_parts: int

    def n_partitions(self, items):
        return self.n_parts

    def assign(self, items):
        key = items[:, 0] if items.ndim > 1 else items
        return (np.asarray(key).astype(np.int64) % self.n_parts
                ).astype(np.int32)

    def assign_device(self, items):
        key = items[:, 0] if items.ndim > 1 else items
        return key.astype(jnp.int32) % self.n_parts


class Reducer:
    """Reduce stage: a per-partition kernel (traced under ``lax.map`` /
    ``shard_map``, so fixed output shape) plus a host-side ``finalize``.
    Partition results are combined by summation (psum across the mesh)."""

    pad_value: float = 0.0   # fill for capacity padding; pick one kernels ignore

    # cost-model basis for tile="auto" planning (class attr, not a field):
    # "pairs" = work quadratic in score cells (cross-row reducers);
    # "rows"  = work linear in owned rows (monoid/bincount reducers), where
    # extra tiers are mostly fixed overhead. Never affects results — only
    # which tile/tier split the planner predicts fastest.
    cost_basis = "pairs"

    def per_partition(self, owned_p, bucket_p):
        """[C1, d], [C2, d] -> fixed-shape array, summed over partitions."""
        raise NotImplementedError

    def reduce_partitions(self, owned, bucket, n_owned, n_bucket):
        """Batched reduce over a whole size tier: [P, C1, d], [P, C2, d] +
        [P] real counts -> the partition-summed result. Rows at index >=
        count are capacity padding and MUST not contribute.

        Default: re-mask padding to ``pad_value`` and ``lax.map`` the
        per-partition kernel (correct for any reducer). Override with a
        masked batched kernel (leading partition axis) for the hot path.
        """
        mo = jnp.arange(owned.shape[1], dtype=jnp.int32) < n_owned[:, None]
        mb = jnp.arange(bucket.shape[1], dtype=jnp.int32) < n_bucket[:, None]
        owned = jnp.where(mo[..., None], owned, self.pad_value)
        bucket = jnp.where(mb[..., None], bucket, self.pad_value)
        outs = jax.lax.map(lambda ab: self.per_partition(ab[0], ab[1]),
                           (owned, bucket))
        return jax.tree.map(lambda o: jnp.sum(o, axis=0), outs)

    def reduce_traceable(self) -> bool:
        """Whether ``reduce_partitions`` is pure traced jax — callable inside
        a ``shard_map`` region. The default masked ``lax.map`` is; reducers
        that delegate to the z-banded blocked engine (host-side block
        planning) are not, and the sharded reduce falls back to eager
        per-shard slicing with a psum combine of the partials."""
        return True

    def finalize(self, total, sd: "ShuffledData"):
        """Host-side post-combine (dedup corrections, differencing, ...)."""
        return np.asarray(total)

    def flops(self, sd: "ShuffledData") -> float:
        """Estimated reduce-stage FLOPs, for StageStats/Amdahl accounting."""
        return 0.0

    def combiner(self):
        """Map-side combine plugin (an ``executor.Combiner``) for this
        reducer, or None when per-split reduce outputs cannot be merged into
        the whole-catalog answer (any reducer whose kernel couples rows
        ACROSS items, e.g. pair counting — a pair spanning two splits is
        seen by neither split alone). Reducers whose output is a
        commutative-monoid fold over individual owned rows (wordcount's
        token histogram) return one, and the streaming executor then keeps
        only the combined accumulator across splits."""
        return None


class _PaddingAccounting:
    """Shared padded-vs-real capacity accounting (both engines' ShuffledData
    expose these; reducer ``flops`` estimates are written against them)."""

    @property
    def pair_cells(self) -> float:
        """Total padded (owned x bucket) cells the reduce kernels cover."""
        raise NotImplementedError

    @property
    def owned_cells(self) -> float:
        """Total padded owned-capacity rows."""
        raise NotImplementedError

    @property
    def real_pair_cells(self) -> float:
        no = np.asarray(self.n_owned, np.float64)
        nb = np.asarray(self.n_bucket, np.float64)
        return float(np.sum(no * nb))

    @property
    def padded_ratio(self) -> float:
        """pair_cells / real_pair_cells — how much compute the capacity
        padding inflates (the fig3 ``bigger_blocks`` inversion in one
        number)."""
        real = self.real_pair_cells
        return self.pair_cells / real if real else 1.0


@dataclasses.dataclass
class ShuffledData(_PaddingAccounting):
    """Post-shuffle state: fixed-capacity padded per-partition arrays."""

    owned: np.ndarray          # [P, C1, d] (pad_value-padded)
    bucket: np.ndarray         # [P, C2, d] owned + replicas (pad_value-padded)
    n_owned: np.ndarray        # [P] int32 real counts
    n_bucket: np.ndarray       # [P] int32 real counts

    @property
    def pair_cells(self) -> float:
        P, C1, _ = self.owned.shape
        return float(P) * C1 * self.bucket.shape[1]

    @property
    def owned_cells(self) -> float:
        return float(self.owned.shape[0]) * self.owned.shape[1]


@dataclasses.dataclass
class TierData:
    """One capacity size-class of the device shuffle: all partitions whose
    bucket fits in C2 rows, padded to one [Pt, C*, ...] layout. Under a
    ``data``-axis mesh, ``Pt`` is rounded up to a multiple of the axis size
    with *phantom* partitions (all-padding rows, zero real counts) so the
    tier splits evenly across shards; the masked kernels ignore them."""

    part_ids: np.ndarray       # [P_real] global partition ids (host)
    owned_wire: tuple          # codec wire arrays, leading dims [Pt, C1]
    bucket_wire: tuple         # codec wire arrays, leading dims [Pt, C2]
    n_owned: jax.Array         # [Pt] int32 real counts (device; 0 = phantom)
    n_bucket: jax.Array        # [Pt] int32 real counts (device; 0 = phantom)
    C1: int = 0
    C2: int = 0
    Pt: int = 0                # padded partition rows (multiple of n_shards)

    @property
    def nbytes(self) -> int:
        return sum(int(w.size) * w.dtype.itemsize
                   for w in (*self.owned_wire, *self.bucket_wire))


@dataclasses.dataclass
class DeviceShuffledData(_PaddingAccounting):
    """Post-shuffle state of the device engine: wire-dtype payloads grouped
    into capacity tiers. ``n_owned``/``n_bucket`` are the global per-partition
    real counts (host arrays), so reducer ``finalize`` hooks work unchanged
    across engines."""

    tiers: list
    n_owned: np.ndarray        # [P] int32 (host)
    n_bucket: np.ndarray       # [P] int32 (host)

    @property
    def pair_cells(self) -> float:
        return float(sum(t.Pt * t.C1 * t.C2 for t in self.tiers))

    @property
    def owned_cells(self) -> float:
        return float(sum(t.Pt * t.C1 for t in self.tiers))


@dataclasses.dataclass
class MapReduceJob:
    """A named composition of the three pluggable stages.

    ``codec="auto"`` / ``tile="auto"`` delegate the choice to the cost
    model (``core/cost_model.py``): codec resolves at job entry (exact
    codecs only, so arithmetic never changes), tile at shuffle time when
    the per-partition counts are known. Both default to the historical
    concrete values — auto is opt-in."""

    name: str
    partitioner: Partitioner
    reducer: Reducer
    codec: str | ShuffleCodec = "identity"
    tile: int | str = 256      # capacity quantum (the paper's block size)


def resolve_auto_job(job: MapReduceJob) -> MapReduceJob:
    """Materialize ``codec="auto"`` via the cost model. Exact codecs only —
    auto choices change shapes, never arithmetic. ``tile="auto"`` stays on
    the job: it resolves inside ``_shuffle_mapped`` where the per-partition
    counts exist."""
    if job.codec == "auto":
        from repro.core.cost_model import get_cost_model
        job = dataclasses.replace(job, codec=get_cost_model().choose_codec())
    return job


@dataclasses.dataclass
class JobResult:
    output: object
    stats: StageStats


# ---------------------------------------------------------------------------
# Host engine (oracle parity + mesh sharding)
# ---------------------------------------------------------------------------

def shuffle_stage(items, partitioner: Partitioner, codec="identity", *,
                  tile: int = 256, pad_partitions_to: int = 1,
                  pad_value: float = 0.0,
                  stats: StageStats | None = None) -> ShuffledData:
    """Map (assign + replicate) then shuffle (codec wire trip, pad, stack).

    The codec round-trips the payload exactly as the wire would see it —
    except for *exact* codecs (``identity``), whose no-op encode/decode is
    skipped entirely (``ShuffleCodec.roundtrip``); ``shuffle_wire_bytes``
    always comes from the static ``codec.nbytes`` formula, so no encoded
    copy is ever materialized just for accounting. Wire bytes count every
    point that lands in a bucket (owned + border copies), matching the
    paper's "bytes that crossed the shuffle" accounting.

    ``codec="auto"`` resolves through the cost model; ``tile="auto"`` takes
    the historical host default (the host engine's results are tile-
    independent — padding is masked — so there is nothing to plan).
    """
    if codec == "auto":
        from repro.core.cost_model import get_cost_model
        codec = get_cost_model().choose_codec()
    if tile == "auto":
        tile = 256
    codec = get_codec(codec)
    items = np.asarray(items)
    if items.ndim == 1:
        items = items[:, None]
    stats = stats if stats is not None else StageStats()

    tr = get_tracer()
    t0 = time.perf_counter()
    P = int(partitioner.n_partitions(items))
    keys = np.asarray(partitioner.assign(items))
    owned_idx = [np.flatnonzero(keys == k) for k in range(P)]
    bucket_idx = [[idx] for idx in owned_idx]
    for dest, idx in partitioner.replicas(items, keys, P):
        bucket_idx[dest].append(np.asarray(idx))
    t1 = time.perf_counter()
    stats.map_wall_s = t1 - t0
    stats.map_bytes = items.nbytes
    if tr.enabled:
        tr.record("map", t0, t1, cat="stage", engine="host")

    t0 = time.perf_counter()
    decoded = codec.roundtrip(items).astype(np.float32)
    P_pad = _round_up(P, pad_partitions_to)
    d = items.shape[1]
    owned_lists = [decoded[i] for i in owned_idx]
    bucket_lists = [decoded[np.concatenate(parts)] for parts in bucket_idx]
    empty = np.zeros((0, d), np.float32)
    owned_lists += [empty] * (P_pad - P)
    bucket_lists += [empty] * (P_pad - P)
    C1 = _round_up(max(len(o) for o in owned_lists), tile)
    C2 = _round_up(max(len(b) for b in bucket_lists), tile)
    sd = ShuffledData(
        owned=np.stack([_pad_rows(o, C1, pad_value) for o in owned_lists]),
        bucket=np.stack([_pad_rows(b, C2, pad_value) for b in bucket_lists]),
        n_owned=np.array([len(o) for o in owned_lists], np.int32),
        n_bucket=np.array([len(b) for b in bucket_lists], np.int32),
    )
    n_shuffled = int(sd.n_bucket.sum())
    t1 = time.perf_counter()
    stats.shuffle_wall_s = t1 - t0
    if tr.enabled:
        tr.record("shuffle", t0, t1, cat="stage", engine="host")
    stats.shuffle_wire_bytes = codec.nbytes(n_shuffled * d)
    stats.shuffle_raw_bytes = 4 * n_shuffled * d
    stats.n_items = len(items)
    stats.n_partitions = P_pad
    stats.codec = codec.name
    stats.engine = "host"
    stats.shuffle_index_impl = "numpy"     # the host shuffle is all numpy
    return sd


def reduce_stage(reducers, sd: ShuffledData, mesh=None):
    """Run every reducer's per-partition kernel in ONE pass over the buckets
    (multi-job batching), summing over partitions — sharded over the mesh's
    ``data`` axis with a psum combine when a mesh is given. -> tuple of
    per-reducer totals."""
    owned, bucket = jnp.asarray(sd.owned), jnp.asarray(sd.bucket)

    def per_part(o, b):
        return tuple(r.per_partition(o, b) for r in reducers)

    if _data_axis_size(mesh) == 1:
        outs = jax.lax.map(lambda ab: per_part(ab[0], ab[1]), (owned, bucket))
        return tuple(jnp.sum(o, axis=0) for o in outs)

    from jax.sharding import PartitionSpec as P

    def body(o, b):
        r = jax.lax.map(lambda ab: per_part(ab[0], ab[1]), (o, b))
        return tuple(jax.lax.psum(jnp.sum(x, axis=0), "data") for x in r)

    D = _data_axis_size(mesh)
    assert owned.shape[0] % D == 0, (owned.shape, dict(mesh.shape))
    spec = P("data", None, None)
    return _shard_map_compat(
        body, mesh=mesh, in_specs=(spec, spec),
        out_specs=tuple(P() for _ in reducers),
        axis_names=frozenset({"data"}))(owned, bucket)


# ---------------------------------------------------------------------------
# Device engine (the hot path): wire-dtype shuffle + tiered masked reduce
# ---------------------------------------------------------------------------

def plan_tiers(n_owned, n_bucket, tile: int, max_tiers: int = 3,
               pad_partitions_to: int = 1, tier_cost=None):
    """Group partitions into <= ``max_tiers`` capacity size classes.

    One global capacity (the host engine's choice) is sized by the most
    skewed partition, so every partition pays the worst partition's padding
    — the fig3 ``bigger_blocks`` inversion. Tiers bound that: partitions are
    grouped by bucket capacity (rounded to the ``tile`` quantum) and each
    tier is padded only to ITS max. The <=2 split points are chosen by
    exact search over distinct capacities, minimizing total tier cost.

    ``tier_cost``: optional vectorized callable ``f(Pt, C1, C2) -> cost``
    over float64 numpy arrays (``Pt`` = phantom-padded partition count) —
    e.g. the cost model's predicted tier wall
    (``CostModel.tier_cost_fn()``). Default: padded pair cells
    ``Pt * C1 * C2``, bit-identical to the historical planner.

    ``pad_partitions_to`` (the mesh's ``data`` axis size): each tier's
    partition count is rounded up to a multiple of it with phantom
    all-padding partitions so the tier splits evenly across shards; the
    cost search charges those phantom rows, so under a wide mesh the
    planner leans toward fewer, fuller tiers.

    The search is a vectorized scan over the O(U^2) segment-cost table of
    unique capacities (the old ``itertools.combinations`` python loop was
    O(U choose 2) cost evaluations — minutes at U=500), with an early-exit
    bound: any prefix tier already costing >= the incumbent best prunes
    every deeper split under it.

    -> list of (part_ids ascending, C1, C2) per tier (part_ids are REAL
    partitions only; the engine appends the phantoms).
    """
    n_owned = np.asarray(n_owned, np.int64)
    n_bucket = np.asarray(n_bucket, np.int64)
    pad = pad_partitions_to
    caps = np.array([_round_up(int(c), tile) for c in n_bucket], np.int64)
    uniq = np.unique(caps)
    U = len(uniq)

    def build(cut_ids):
        tiers, lo = [], -1
        for th in (int(uniq[i]) for i in cut_ids):
            sel = np.flatnonzero((caps > lo) & (caps <= th))
            lo = th
            if len(sel):
                tiers.append((sel, _round_up(int(n_owned[sel].max()), tile),
                              th))
        return tiers

    # Segment-cost table: S[i, j] = cost of one tier covering uniq[i..j]
    # (inclusive; +inf below the diagonal). Costs are exact in float64 —
    # padded-cell counts are integers far below 2**53 — so argmin over S
    # reproduces the python accumulation bit-for-bit.
    ui = np.searchsorted(uniq, caps)
    maxo = np.zeros(U, np.int64)
    np.maximum.at(maxo, ui, n_owned)
    pc = np.concatenate([[0], np.cumsum(np.bincount(ui, minlength=U))])
    row = np.arange(U)[:, None]
    col = np.arange(U)[None, :]
    seg_max = np.maximum.accumulate(
        np.where(col >= row, maxo[None, :], 0), axis=1)
    cnt = pc[1:][None, :] - pc[:-1][:, None]
    Pt = np.maximum(pad, -(-cnt // pad) * pad).astype(np.float64)
    C1 = np.maximum(tile, -(-seg_max // tile) * tile).astype(np.float64)
    C2 = np.broadcast_to(uniq.astype(np.float64)[None, :], (U, U))
    if tier_cost is None:
        S = Pt * C1 * C2
    else:
        S = np.asarray(tier_cost(Pt, C1, C2), np.float64)
    S = np.where(col >= row, S, np.inf)

    best_cost = float(S[0, U - 1])
    best_cuts = (U - 1,)
    if max_tiers >= 2 and U >= 2:
        two = S[0, :U - 1] + S[1:, U - 1]
        c = int(np.argmin(two))          # first occurrence = lexicographic
        if two[c] < best_cost:
            best_cost, best_cuts = float(two[c]), (c, U - 1)
    if max_tiers >= 3 and U >= 3:
        a = S[0, :U - 2]                 # prefix tier ending at cut c1
        keep = a < best_cost             # early-exit bound: prefix alone
        if keep.any():                   # >= incumbent prunes the row
            T = ((a[:, None] + S[1:U - 1, 1:U - 1])
                 + S[2:, U - 1][None, :])
            r2 = np.arange(U - 2)
            T = np.where((r2[:, None] <= r2[None, :]) & keep[:, None],
                         T, np.inf)
            flat = int(np.argmin(T))
            c1, c2 = divmod(flat, U - 2)
            if T[c1, c2] < best_cost:
                best_cost = float(T[c1, c2])
                best_cuts = (c1, c2 + 1, U - 1)
    if max_tiers > 3 and U > 3:
        # deeper splits are rare; exact DFS with the same early-exit bound
        kmax = min(max_tiers, U)

        def dfs(i0, cuts, prefix):
            nonlocal best_cost, best_cuts
            if prefix >= best_cost:
                return
            close = prefix + S[i0, U - 1]
            if close < best_cost:
                best_cost, best_cuts = float(close), tuple(cuts) + (U - 1,)
            if len(cuts) + 2 <= kmax:
                for c in range(i0, U - 1):
                    dfs(c + 1, cuts + [c], prefix + S[i0, c])

        dfs(0, [], 0.0)
    return build(best_cuts)


@functools.partial(jax.jit, static_argnames=("specs", "has_skey"))
def _scatter_tiers_jit(payloads, keys, dest_eff, src, skey, owned_starts,
                       bucket_starts, part_tier, part_local, *, specs,
                       has_skey):
    """Argsort-based bucketing: sort bucket entries by (destination, sort
    key), compute each entry's rank within its partition from the
    exclusive-cumsum starts, and scatter the *wire-dtype* payload rows into
    every tier's padded [Pt, C, ...] layout (entries outside the tier drop
    out of range).

    ``dest_eff`` is [m] with invalid entries set to P (they sort last and
    hit ``part_tier[P] == -1``, so no tier claims them).
    """
    n, m = keys.shape[0], dest_eff.shape[0]
    if has_skey:
        ko = jnp.lexsort((skey, keys))
        bo = jnp.lexsort((skey[src], dest_eff))
    else:
        ko = jnp.argsort(keys)
        bo = jnp.argsort(dest_eff)
    sk = keys[ko]
    orank = jnp.arange(n, dtype=jnp.int32) - owned_starts[sk]
    sd = dest_eff[bo]
    brank = jnp.arange(m, dtype=jnp.int32) - bucket_starts[sd]
    own_rows = tuple(p[ko] for p in payloads)
    bkt_rows = tuple(p[src[bo]] for p in payloads)

    def scatter(rows, pos, Pt, C):
        return tuple(
            jnp.zeros((Pt * C,) + r.shape[1:], r.dtype)
            .at[pos].set(r, mode="drop")
            .reshape((Pt, C) + r.shape[1:]) for r in rows)

    out = []
    for t, (Pt, C1, C2) in enumerate(specs):
        o_pos = jnp.where(part_tier[sk] == t,
                          part_local[sk] * C1 + orank, Pt * C1)
        b_pos = jnp.where(part_tier[sd] == t,
                          part_local[sd] * C2 + brank, Pt * C2)
        out.append((scatter(own_rows, o_pos, Pt, C1),
                    scatter(bkt_rows, b_pos, Pt, C2)))
    return tuple(out)


# On a CPU-only backend the XLA sort/scatter compiles cost more than the
# whole shuffle; index *metadata* ([m] int32 permutations) is then computed
# with vectorized numpy and only the payload moves through jax gathers.
# Accelerator backends keep the pure-jnp path so the payload AND its
# bucketing stay device-resident. Tests pin this to exercise both paths.
# The RESOLVED choice is recorded in ``StageStats.shuffle_index_impl``
# ("jnp" | "host") so an "auto" run under a mesh is never ambiguous about
# which path produced its shuffle metadata; both paths must produce
# identical tier layouts and results (asserted in tests and md_check).
SHUFFLE_INDEX_IMPL = "auto"            # "auto" | "jnp" | "host"


def _use_jnp_indices() -> bool:
    if SHUFFLE_INDEX_IMPL == "auto":
        return jax.default_backend() != "cpu"
    return SHUFFLE_INDEX_IMPL == "jnp"


def _scatter_tiers_host(payloads, keys_h, dest_h, src_h, skey_h, o_starts,
                        b_starts, part_tier, part_local, specs):
    """numpy twin of ``_scatter_tiers_jit``: same argsort/rank math on the
    index metadata, then one jax *gather* per tier (gather maps point padding
    at row n, a zeros sentinel appended to the payload)."""
    n = keys_h.shape[0]
    if skey_h is not None:
        ko = np.lexsort((skey_h, keys_h))
        bo = np.lexsort((skey_h[src_h], dest_h))
    else:
        ko = np.argsort(keys_h, kind="stable")
        bo = np.argsort(dest_h, kind="stable")
    sk = keys_h[ko]
    orank = np.arange(n, dtype=np.int32) - o_starts[sk]
    sd = dest_h[bo]
    brank = np.arange(len(dest_h), dtype=np.int32) - b_starts[sd]
    ssrc = src_h[bo]
    # numpy fancy indexing + one host->device put per tier array: on CPU this
    # beats XLA's eager gather ~5x, and this path only runs on CPU backends
    padded = tuple(np.concatenate(
        [np.asarray(p), np.zeros((1,) + p.shape[1:], p.dtype)])
        for p in payloads)

    def gather(rows, sel_part, rank, srcs, t, Pt, C):
        sel = part_tier[sel_part] == t
        g = np.full(Pt * C, n, np.int32)
        g[part_local[sel_part[sel]] * C + rank[sel]] = srcs[sel]
        return tuple(jnp.asarray(p[g].reshape((Pt, C) + p.shape[1:]))
                     for p in rows)

    out = []
    for t, (Pt, C1, C2) in enumerate(specs):
        out.append((gather(padded, sk, orank, ko.astype(np.int32), t, Pt, C1),
                    gather(padded, sd, brank, ssrc, t, Pt, C2)))
    return tuple(out)


def _make_sharded_body(reducers, codec, mesh):
    """shard_map'd decode + masked reduce + psum for traceable reducers."""
    from jax.sharding import PartitionSpec as P

    def body(ow, bw, no, nb):
        owned = codec.decode_device(*ow)
        bucket = codec.decode_device(*bw)
        outs = tuple(r.reduce_partitions(owned, bucket, no, nb)
                     for r in reducers)
        return jax.tree.map(lambda x: jax.lax.psum(x, "data"), outs)

    shard = P("data")                   # prefix spec: shard axis 0, rest repl
    return _shard_map_compat(
        body, mesh=mesh, in_specs=(shard, shard, shard, shard),
        out_specs=P(), axis_names=frozenset({"data"}))


def _make_psum_combine(mesh):
    """shard_map'd psum of stacked [D, ...] per-shard partial pytrees."""
    from jax.sharding import PartitionSpec as P

    def combine(t):
        return jax.tree.map(
            lambda x: jax.lax.psum(jnp.sum(x, axis=0), "data"), t)

    return _shard_map_compat(combine, mesh=mesh, in_specs=(P("data"),),
                             out_specs=P(), axis_names=frozenset({"data"}))


# The shard_map'd callables must be REUSED across calls for jit's internal
# shape cache to hit (it keys on function identity; a fresh closure per
# run_job would retrace + recompile every tier of every run). Keys are
# hashable for the stock stages (frozen-dataclass reducers, registry codec
# singletons, meshes); unhashable custom stages fall back to an uncached
# build and pay the retrace.
_make_sharded_body_cached = functools.lru_cache(maxsize=None)(
    _make_sharded_body)
_make_psum_combine_cached = functools.lru_cache(maxsize=None)(
    _make_psum_combine)


def _reduce_tier_sharded(reducers, codec, tier: TierData, mesh):
    """Reduce one tier across the mesh's ``data`` axis and psum-combine.

    Tier rows are contiguous per shard (shard ``s`` owns rows
    ``[s*Pt/D, (s+1)*Pt/D)``; phantom partitions mask to nothing). Two
    sub-paths mirror the ``ops.py`` backend split:

    - every reducer traceable (Pallas masked kernels on TPU, pure-jnp
      reducers anywhere): decode + masked reduce + ``lax.psum`` run INSIDE
      one ``shard_map`` region, so the wire payload is resharded once and
      each shard's kernels run on its own device.
    - otherwise (the z-banded blocked engine plans its blocks on the host,
      which cannot happen under tracing): each shard's rows are sliced and
      reduced eagerly, then the stacked per-shard partials cross ONE
      ``shard_map`` psum. Bit-identical either way — the accumulators are
      integers and all engines share the ``_dots2d`` score formulation.

    -> tuple of per-reducer totals (replicated).
    """
    D = _data_axis_size(mesh)
    if all(r.reduce_traceable() for r in reducers):
        try:
            fn = _make_sharded_body_cached(reducers, codec, mesh)
        except TypeError:               # unhashable custom reducer/codec
            fn = _make_sharded_body(reducers, codec, mesh)
        return fn(tier.owned_wire, tier.bucket_wire, tier.n_owned,
                  tier.n_bucket)

    q = tier.Pt // D
    partials = []
    for s in range(D):
        sl = slice(s * q, (s + 1) * q)
        owned = codec.decode_device(*(w[sl] for w in tier.owned_wire))
        bucket = codec.decode_device(*(w[sl] for w in tier.bucket_wire))
        partials.append(tuple(
            r.reduce_partitions(owned, bucket, tier.n_owned[sl],
                                tier.n_bucket[sl]) for r in reducers))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *partials)
    try:
        combine = _make_psum_combine_cached(mesh)
    except TypeError:
        combine = _make_psum_combine(mesh)
    return combine(stacked)


@dataclasses.dataclass
class MappedSplit:
    """Device-resident output of the map stage for ONE catalog split: the
    codec wire payload plus the bucket-entry index metadata. This is the
    unit the streaming executor (``executor.py``) moves between stages —
    splits are mapped one at a time and either reduced immediately (combine
    mode) or accumulated via ``concat_mapped`` and reduced once (the raw
    float32 split can be dropped as soon as its ``MappedSplit`` exists; only
    wire-dtype arrays persist)."""

    payloads: tuple            # codec wire arrays, leading axis = n_rows
    keys: jax.Array            # [n] int32 owning partition per row
    dest_eff: jax.Array        # [m] int32 bucket destinations (invalid -> P)
    src: jax.Array             # [m] int32 row index into payloads
    skey: object               # [n] secondary sort key or None
    n_rows: int = 0
    d: int = 0
    nbytes_in: int = 0         # raw input bytes (map_bytes accounting)


def map_split_device(partitioner: Partitioner, codec: ShuffleCodec, items,
                     P: int) -> MappedSplit:
    """Map stage for one split: partition assignment + border replication as
    jax ops, payload encoded straight to the codec's wire dtype. Pure
    dispatch — nothing here blocks, so a caller can map split k while split
    k-1 still reduces."""
    with get_tracer().span("map", cat="stage", engine="device"):
        if not isinstance(items, jax.Array):
            items = np.asarray(items)
        if items.ndim == 1:
            items = items[:, None]
        items_dev = jnp.asarray(items, jnp.float32)
        keys = partitioner.assign_device(items_dev)
        dest, src, valid = partitioner.bucket_entries_device(items_dev,
                                                            keys, P)
        dest_eff = jnp.where(valid, dest, P).astype(jnp.int32)
        src = jnp.asarray(src, jnp.int32)
        payloads = codec.encode_device(items_dev)
        skey = partitioner.sort_key_device(items_dev)
        return MappedSplit(payloads, keys, dest_eff, src, skey,
                           n_rows=int(items.shape[0]), d=int(items.shape[1]),
                           nbytes_in=int(items.nbytes))


def concat_mapped(splits: "list[MappedSplit]") -> MappedSplit:
    """Merge per-split map outputs into one stream (device concat; source
    row indices are offset into the concatenated payload). Entry ORDER
    differs from a monolithic map over the concatenated catalog — bucket
    contents are identical as multisets, and partition reductions are
    commutative sums, so results are bit-identical (asserted in tests)."""
    if len(splits) == 1:
        return splits[0]
    offs = np.cumsum([0] + [s.n_rows for s in splits[:-1]])
    skeys = [s.skey for s in splits]
    return MappedSplit(
        payloads=tuple(jnp.concatenate(ps)
                       for ps in zip(*(s.payloads for s in splits))),
        keys=jnp.concatenate([s.keys for s in splits]),
        dest_eff=jnp.concatenate([s.dest_eff for s in splits]),
        src=jnp.concatenate([s.src + np.int32(o)
                             for s, o in zip(splits, offs)]),
        skey=(None if any(sk is None for sk in skeys)
              else jnp.concatenate(skeys)),
        n_rows=int(sum(s.n_rows for s in splits)),
        d=splits[0].d,
        nbytes_in=int(sum(s.nbytes_in for s in splits)))


@dataclasses.dataclass
class ResidentCatalog:
    """Device-resident post-shuffle handle: a catalog mapped and shuffled
    ONCE into tiered wire-dtype partitions that stay on device (sharded over
    the mesh's ``data`` axis when one is given), plus the shuffle signature
    (partitioner / codec / tile / pad_value) that defines which jobs may
    reduce against it.

    ``shuffle_reduce_device`` builds one per call and reduces through it
    immediately — the one-shot path. The MapReduce query service
    (``serving/mr_service.py``) instead keeps one alive across many
    requests, so N queries cost one shuffle ever plus N fused batched
    reduces (which also reuse the module-level jit/shard_map caches — they
    key on reducers/codec/mesh, not on the catalog)."""

    partitioner: Partitioner
    codec: ShuffleCodec
    tile: int
    pad_value: float
    sd: DeviceShuffledData
    P: int
    mesh: object = None
    shard_pad: np.ndarray = None       # [D] padded pair cells per shard
    shard_real: np.ndarray = None      # [D] real pair cells per shard
    n_rows: int = 0
    d: int = 0
    load_stats: StageStats = None      # the shuffle-once cost (set by shuffle_once)
    tile_resolved: int = 0             # concrete tile when ``tile == "auto"``

    @property
    def nbytes(self) -> int:
        """Resident wire bytes held on device across requests."""
        return sum(t.nbytes for t in self.sd.tiers)

    def validate(self, jobs) -> None:
        """Jobs must share this catalog's shuffle signature to reduce
        against it (same contract as ``validate_batch``, anchored here)."""
        for j in jobs:
            diffs = [k for k, a, b in [
                ("partitioner", j.partitioner, self.partitioner),
                ("codec", get_codec(j.codec).name, self.codec.name),
                ("tile", j.tile, self.tile),
                ("pad_value", j.reducer.pad_value, self.pad_value),
            ] if a != b]
            if diffs:
                raise ValueError(
                    f"job {j.name!r} cannot reduce against this resident "
                    f"catalog: differs in {', '.join(diffs)}")

    def reduce_totals(self, reducers, stats: StageStats):
        """Tiered masked reduce of ``reducers`` over the resident tiers —
        the reduce half of ``shuffle_reduce_device``, with the same
        accumulate (``+=``) stats contract. Decode happens on-device per
        pass; under a data-axis mesh each tier reduces psum-sharded."""
        D = _data_axis_size(self.mesh)
        t0 = time.perf_counter()
        totals = None
        for tier in self.sd.tiers:
            if D > 1:
                outs = _reduce_tier_sharded(reducers, self.codec, tier,
                                            self.mesh)
            else:
                owned = self.codec.decode_device(*tier.owned_wire)
                bucket = self.codec.decode_device(*tier.bucket_wire)
                outs = tuple(r.reduce_partitions(owned, bucket, tier.n_owned,
                                                 tier.n_bucket)
                             for r in reducers)
            totals = outs if totals is None else tuple(
                jax.tree.map(jnp.add, a, b) for a, b in zip(totals, outs))
        totals = jax.block_until_ready(totals)
        t1 = time.perf_counter()
        stats.reduce_wall_s += t1 - t0
        tr = get_tracer()
        if tr.enabled:
            tr.record("reduce", t0, t1, cat="stage", engine="device",
                      tiers=len(self.sd.tiers))
        stats.reduce_bytes += self.nbytes
        flops = float(sum(r.flops(self.sd) for r in reducers))
        stats.reduce_flops += flops
        # predicted reduce wall from the same accounting the stats carry:
        # reducer flops + decoded score cells and resident wire traffic
        from repro.core.cost_model import StageCost, get_cost_model
        cells = self.sd.pair_cells
        stats.predicted_reduce_wall_s += get_cost_model().predict_wall(
            StageCost(flops=flops,
                      hbm_bytes=4.0 * cells * len(reducers) + self.nbytes,
                      n_dispatch=max(cells / (64.0 * 64.0 * 512.0), 1.0)
                      * len(self.sd.tiers)))
        return totals

    def run(self, jobs, stats: StageStats = None) -> "list[JobResult]":
        """Serve ``jobs`` (one or a batch) against the resident tiers with a
        single fused reduce pass — no map, no shuffle: those were paid once
        at ``shuffle_once``. -> one JobResult per job, sharing one
        StageStats whose map/shuffle walls are zero by construction."""
        jobs = [jobs] if isinstance(jobs, MapReduceJob) else list(jobs)
        self.validate(jobs)
        if stats is None:
            stats = StageStats(job="+".join(j.name for j in jobs))
        stats.engine = "device"
        stats.codec = self.codec.name
        stats.n_items = self.n_rows
        stats.n_partitions = self.P
        stats.n_shards = _data_axis_size(self.mesh)
        stats.reduce_padded_ratio = self.sd.padded_ratio
        stats.shard_padded_ratio = tuple(
            float(p / max(r, 1.0))
            for p, r in zip(self.shard_pad, self.shard_real))
        meter = get_meter()
        mtok = meter.begin()
        totals = self.reduce_totals(tuple(j.reducer for j in jobs), stats)
        meter.attribute(mtok, stats)
        return [JobResult(j.reducer.finalize(t, self.sd), stats)
                for j, t in zip(jobs, totals)]


def _shuffle_mapped(partitioner: Partitioner, codec: ShuffleCodec, tile,
                    pad_value: float, m: MappedSplit, P: int,
                    stats: StageStats, mesh=None,
                    cost_basis: str = "pairs") -> ResidentCatalog:
    """Shuffle one mapped stream into device-resident tiers: count, tier,
    argsort-bucket, scatter in wire dtype — the shuffle half of
    ``shuffle_reduce_device``, accumulating (``+=``) into ``stats``. Tier
    partition counts are padded to a multiple of the mesh's data axis size
    with phantom (zero-count) partitions, so every tier splits evenly
    across shards.

    ``tile="auto"`` asks the cost model for the tile quantum AND the tier
    split minimizing the predicted reduce wall (instead of padded-cell
    count); the resolved tile lands in ``stats.auto_tile`` and
    ``ResidentCatalog.tile_resolved``. Either way the predicted shuffle
    wall is recorded so model error is observable per stage.
    -> ResidentCatalog."""
    from repro.core.cost_model import StageCost, get_cost_model
    D = _data_axis_size(mesh)
    d = m.d
    t0 = time.perf_counter()
    keys_h = np.asarray(jax.block_until_ready(m.keys))
    dest_h = np.asarray(m.dest_eff)
    # keys == P marks payload-only rows (carried for the bucket entries that
    # reference them — spilled range reads use this for cross-range border
    # rows); like dest == P they are excluded from owned counts/scatter.
    n_owned = np.bincount(keys_h, minlength=P + 1)[:P].astype(np.int64)
    n_bucket = np.bincount(dest_h, minlength=P + 1)[:P].astype(np.int64)
    tile_req = tile
    if tile == "auto":
        tile, plan, _ = get_cost_model().plan_shuffle(n_owned, n_bucket, D,
                                                      d=d, basis=cost_basis)
        stats.auto_tile = int(tile)
    else:
        plan = plan_tiers(n_owned, n_bucket, tile, pad_partitions_to=D)
    part_tier = np.full(P + 1, -1, np.int32)
    part_local = np.zeros(P + 1, np.int32)
    specs = []
    for t, (ids, C1, C2) in enumerate(plan):
        part_tier[ids] = t
        part_local[ids] = np.arange(len(ids), dtype=np.int32)
        specs.append((_round_up(len(ids), D), C1, C2))
    o_starts = np.zeros(P + 1, np.int32)
    np.cumsum(n_owned, out=o_starts[1:])
    b_starts = np.zeros(P + 1, np.int32)
    np.cumsum(n_bucket, out=b_starts[1:])
    stats.shuffle_index_impl = "jnp" if _use_jnp_indices() else "host"
    if _use_jnp_indices():
        scattered = _scatter_tiers_jit(
            m.payloads, m.keys, m.dest_eff, m.src,
            jnp.zeros(0) if m.skey is None else m.skey,
            jnp.asarray(o_starts), jnp.asarray(b_starts),
            jnp.asarray(part_tier), jnp.asarray(part_local),
            specs=tuple(specs), has_skey=m.skey is not None)
    else:
        src_h = np.asarray(m.src)
        live = dest_h < P           # drop non-replicated border slots before
        if not live.all():          # sorting: fewer copies = less sort work
            dest_h, src_h = dest_h[live], src_h[live]
        scattered = _scatter_tiers_host(
            m.payloads, keys_h, dest_h, src_h,
            None if m.skey is None else np.asarray(m.skey), o_starts,
            b_starts, part_tier, part_local, tuple(specs))
    scattered = jax.block_until_ready(scattered)
    tiers = []
    shard_pad = np.zeros(D, np.float64)
    shard_real = np.zeros(D, np.float64)
    for ((ids, C1, C2), (Pt, _, _), (own, bkt)) in zip(plan, specs, scattered):
        no_t = np.zeros(Pt, np.int64)
        nb_t = np.zeros(Pt, np.int64)
        no_t[:len(ids)] = n_owned[ids]
        nb_t[:len(ids)] = n_bucket[ids]
        tiers.append(TierData(ids, own, bkt, jnp.asarray(no_t, jnp.int32),
                              jnp.asarray(nb_t, jnp.int32), C1=C1, C2=C2,
                              Pt=Pt))
        shard_real += (no_t * nb_t).reshape(D, Pt // D).sum(axis=1)
        shard_pad += float(Pt // D) * C1 * C2
    sd = DeviceShuffledData(tiers, n_owned, n_bucket)
    n_shuffled = int(n_bucket.sum())
    wire = n_shuffled * codec.device_bytes_per_item(d)
    t1 = time.perf_counter()
    stats.shuffle_wall_s += t1 - t0
    tr = get_tracer()
    if tr.enabled:
        tr.record("shuffle", t0, t1, cat="stage", engine="device")
    stats.shuffle_wire_bytes += wire
    stats.shuffle_raw_bytes += 4 * n_shuffled * d
    # predicted shuffle wall: the sort/scatter is byte-bound — payload rows
    # make ~3 passes and the index stream ~16B per shuffled row
    stats.predicted_shuffle_wall_s += get_cost_model().predict_wall(
        StageCost(flops=0.0, hbm_bytes=3.0 * wire + 16.0 * n_shuffled,
                  n_dispatch=len(plan) + 2))
    stats.n_items += m.n_rows
    stats.n_partitions = P
    stats.codec = codec.name
    stats.engine = "device"
    stats.n_shards = D
    return ResidentCatalog(partitioner, codec, tile_req, pad_value, sd, P,
                           mesh=mesh, shard_pad=shard_pad,
                           shard_real=shard_real, n_rows=m.n_rows, d=d,
                           tile_resolved=int(tile))


def shuffle_once(partitioner: Partitioner, items, *, codec="identity",
                 tile: int | str = 256, pad_value: float = 0.0, mesh=None,
                 stats: StageStats = None) -> ResidentCatalog:
    """Load + map + shuffle a catalog ONCE into device-resident tiered
    wire-dtype partitions. The returned handle's ``run(jobs)`` serves any
    batch of signature-compatible jobs as a pure fused reduce — the
    shuffle-then-reduce decomposition that ``run_jobs`` executes per call
    and the MR query service amortizes across requests. The shuffle cost
    lands in ``stats`` (also kept as ``ResidentCatalog.load_stats``)."""
    if codec == "auto":
        from repro.core.cost_model import get_cost_model
        codec = get_cost_model().choose_codec()
    codec = get_codec(codec)
    if stats is None:
        stats = StageStats(job="shuffle_once")
    P = int(partitioner.n_partitions(
        items if isinstance(items, jax.Array) else np.asarray(items)))
    meter = get_meter()
    mtok = meter.begin()
    t0 = time.perf_counter()
    m = map_split_device(partitioner, codec, items, P)
    stats.map_wall_s += time.perf_counter() - t0
    stats.map_bytes += m.nbytes_in
    cat = _shuffle_mapped(partitioner, codec, tile, pad_value, m, P, stats,
                          mesh)
    meter.attribute(mtok, stats)
    cat.load_stats = stats
    return cat


def shuffle_reduce_device(jobs, m: MappedSplit, P: int, stats: StageStats,
                          mesh=None):
    """Shuffle + reduce one mapped stream (a single split, or the
    ``concat_mapped`` accumulation of many): count, tier, argsort-bucket,
    scatter in wire dtype, then the tiered masked reduce — sharded over the
    mesh's ``data`` axis with a psum combine when one is given. Decomposed
    as ``_shuffle_mapped`` (-> ``ResidentCatalog``) followed by
    ``ResidentCatalog.reduce_totals``, the same two halves the query
    service runs at catalog-load and per-request time.

    Wall/byte stats ACCUMULATE (``+=``) so streaming runs can call this per
    split; ratio-style fields (``reduce_padded_ratio``/``shard_padded_ratio``)
    are left to the caller, which receives the per-call padded/real cell
    vectors. -> (per-job totals, DeviceShuffledData, shard_pad, shard_real).

    Lane-safety: this call (and ``host_shuffle_reduce``/``map_split_device``)
    keeps NO shared mutable state beyond ``stats`` — the module-level
    jit/shard_map caches are ``lru_cache`` (thread-safe) and everything else
    is local — so concurrent lanes (``executor.LanePool``) may run it on
    independent splits simultaneously, each passing its own private
    ``StageStats`` and merging at commit.
    """
    j0 = jobs[0]
    cat = _shuffle_mapped(j0.partitioner, get_codec(j0.codec), j0.tile,
                          j0.reducer.pad_value, m, P, stats, mesh,
                          cost_basis=getattr(j0.reducer, "cost_basis",
                                             "pairs"))
    totals = cat.reduce_totals(tuple(j.reducer for j in jobs), stats)
    return totals, cat.sd, cat.shard_pad, cat.shard_real


@dataclasses.dataclass
class StreamSummary:
    """Aggregate post-shuffle state of a streaming run — what
    ``Reducer.finalize`` sees instead of a materialized ``ShuffledData``.
    ``n_owned``/``n_bucket`` are per-partition counts SUMMED over splits (or
    stitched over partition ranges), so count-based corrections (self-pair
    removal etc.) work unchanged."""

    n_owned: np.ndarray        # [P] int64
    n_bucket: np.ndarray       # [P] int64
    pair_cells: float = 0.0
    owned_cells: float = 0.0
    real_pair_cells: float = 0.0

    @property
    def padded_ratio(self) -> float:
        return (self.pair_cells / self.real_pair_cells
                if self.real_pair_cells else 1.0)


def shuffle_reduce_device_streamed(jobs, ranges, P: int, stats: StageStats,
                                   mesh=None):
    """Shuffle + reduce an ENTRY STREAM of partition ranges — the external
    shuffle's read-back path. ``ranges`` yields ``(lo, hi, m)`` records
    covering disjoint ``[lo, hi)`` slices of the global partition space,
    where ``m`` is a ``MappedSplit`` whose ids are RANGE-LOCAL: keys in
    ``[0, hi-lo)`` for rows the range owns (``hi-lo`` marks payload-only
    border rows carried for bucket entries), ``dest_eff`` in ``[0, hi-lo]``.

    Each range runs the ordinary ``shuffle_reduce_device`` with
    ``P = hi - lo`` — peak resident wire bytes are one range's, not the
    catalog's — and per-job totals tree-add across ranges (disjoint owned
    partitions + commutative integer sums, the same contract that makes
    ``concat_mapped`` order-independent). Per-partition counts stitch into
    global ``[P]`` vectors so finalize corrections see the monolithic view.

    -> (per-job totals, StreamSummary over all ranges, shard_pad,
    shard_real) — the ``shuffle_reduce_device`` return shape with the
    summary standing in for ``DeviceShuffledData``.
    """
    totals = None
    n_owned = np.zeros(P, np.int64)
    n_bucket = np.zeros(P, np.int64)
    pair_pad = pair_real = owned_cells = 0.0
    shard_pad = shard_real = None
    for lo, hi, m in ranges:
        t, sd, sp, sr = shuffle_reduce_device(jobs, m, hi - lo, stats, mesh)
        totals = t if totals is None else tuple(
            jax.tree.map(jnp.add, a, b) for a, b in zip(totals, t))
        n_owned[lo:hi] += sd.n_owned
        n_bucket[lo:hi] += sd.n_bucket
        pair_pad += sd.pair_cells
        pair_real += sd.real_pair_cells
        owned_cells += sd.owned_cells
        if shard_pad is None:
            shard_pad = np.asarray(sp, np.float64).copy()
            shard_real = np.asarray(sr, np.float64).copy()
        else:
            shard_pad += sp
            shard_real += sr
    if totals is None:
        raise ValueError("shuffle_reduce_device_streamed: empty range "
                         "stream — the caller must supply at least one "
                         "range (an all-empty spill still reads one)")
    stats.n_partitions = P
    summary = StreamSummary(n_owned, n_bucket, pair_cells=pair_pad,
                            owned_cells=owned_cells,
                            real_pair_cells=pair_real)
    return totals, summary, shard_pad, shard_real


def host_shuffle_reduce(jobs, items, stats: StageStats, mesh=None):
    """The host engine's shuffle + reduce for one item stream (numpy shuffle
    + ``lax.map`` reduce, sharded over the mesh's ``data`` axis when given)
    — the oracle twin of ``shuffle_reduce_device`` with the same accumulate
    (``+=``) stats contract and return shape.
    -> (per-job totals, ShuffledData, shard_pad, shard_real)."""
    j0 = jobs[0]
    codec = get_codec(j0.codec)
    D = _data_axis_size(mesh)
    local = StageStats()
    sd = shuffle_stage(items, j0.partitioner, codec, tile=j0.tile,
                       pad_partitions_to=D,
                       pad_value=j0.reducer.pad_value, stats=local)
    stats.map_wall_s += local.map_wall_s
    stats.map_bytes += local.map_bytes
    stats.shuffle_wall_s += local.shuffle_wall_s
    stats.shuffle_wire_bytes += local.shuffle_wire_bytes
    stats.shuffle_raw_bytes += local.shuffle_raw_bytes
    stats.n_items += local.n_items
    stats.n_partitions = local.n_partitions
    stats.codec = local.codec
    stats.engine = "host"
    stats.shuffle_index_impl = local.shuffle_index_impl
    stats.n_shards = D
    q = sd.owned.shape[0] // D
    cells = (sd.n_owned.astype(np.float64)
             * sd.n_bucket).reshape(D, q).sum(axis=1)
    pad_cells = float(q) * sd.owned.shape[1] * sd.bucket.shape[1]
    t0 = time.perf_counter()
    totals = jax.block_until_ready(
        reduce_stage([j.reducer for j in jobs], sd, mesh))
    t1 = time.perf_counter()
    stats.reduce_wall_s += t1 - t0
    tr = get_tracer()
    if tr.enabled:
        tr.record("reduce", t0, t1, cat="stage", engine="host")
    stats.reduce_bytes += sd.owned.nbytes + sd.bucket.nbytes
    stats.reduce_flops += float(sum(j.reducer.flops(sd) for j in jobs))
    return totals, sd, np.full(D, pad_cells), np.asarray(cells, np.float64)


# ---------------------------------------------------------------------------
# Entry points (one-split special case of the streaming executor)
# ---------------------------------------------------------------------------

def shuffle_signature(job: MapReduceJob) -> tuple:
    """The (partitioner, codec name, tile, pad_value) key of a job's
    map+shuffle stages. Jobs sharing it can batch over ONE shuffle
    (``run_jobs``) or reduce against one ``ResidentCatalog``."""
    return (job.partitioner, get_codec(job.codec).name, job.tile,
            job.reducer.pad_value)


def group_batch_compatible(jobs) -> "list[list[MapReduceJob]]":
    """Partition ``jobs`` into the fewest groups that each share one shuffle
    signature (order preserved within a group) — how the MR query service
    coalesces an admission window's requests into fused reduce passes."""
    groups: list[list[MapReduceJob]] = []
    sigs: list[tuple] = []
    for j in jobs:
        sig = shuffle_signature(j)
        for g, s in zip(groups, sigs):
            if s == sig:
                g.append(j)
                break
        else:
            groups.append([j])
            sigs.append(sig)
    return groups


def validate_batch(jobs) -> None:
    """Batched jobs must share one shuffle (partitioner/codec/tile/pad)."""
    j0 = jobs[0]
    c0 = get_codec(j0.codec)
    for j in jobs[1:]:
        diffs = [k for k, a, b in [
            ("partitioner", j.partitioner, j0.partitioner),
            ("codec", get_codec(j.codec).name, c0.name),
            ("tile", j.tile, j0.tile),
            ("pad_value", j.reducer.pad_value, j0.reducer.pad_value),
        ] if a != b]
        if diffs:
            raise ValueError(
                f"batched jobs must share one shuffle: {j.name!r} differs "
                f"from {j0.name!r} in {', '.join(diffs)}")


def run_jobs(jobs, items, *, mesh=None, engine: str = "auto",
             split_rows=None) -> list[JobResult]:
    """Execute several jobs that share partitioner/codec/tile through ONE
    map+shuffle and one fused reduce pass (e.g. Neighbor Searching and
    Neighbor Statistics over the same catalog cost a single data pass).

    This is the ONE-SPLIT special case of the streaming executor
    (``mapreduce/executor.py``): the whole catalog is a single
    ``ArraySplits`` split, no combiner, no prefetch — the identical
    map/shuffle/reduce code path the executor runs per split, so streaming
    over N splits is bit-identical to this for exact codecs.

    ``engine``: ``"device"`` (wire-dtype shuffle + tiered masked batched
    reduce; under a data-axis ``mesh`` the tiers shard over ``data`` and
    tier partials combine with a psum), ``"host"`` (numpy shuffle +
    ``lax.map`` reduce; the oracle-parity path, on or off mesh), or
    ``"auto"`` (always device — both engines shard over any data-axis
    mesh). -> one JobResult per job, sharing a single StageStats.

    ``split_rows``: ``None`` (default) runs the whole catalog as one split;
    an int streams it in row chunks of that size; ``"auto"`` asks the cost
    model for a chunk size that amortizes per-split dispatch overhead while
    bounding the working set. Streaming is bit-identical to monolithic for
    exact codecs, so this only changes shapes, never results."""
    from repro.data.pipeline import ArraySplits
    from repro.mapreduce.executor import run_jobs_streaming
    rows = np.asarray(items)
    if split_rows == "auto":
        from repro.core.cost_model import get_cost_model
        d = rows.shape[1] if rows.ndim > 1 else 1
        split_rows = get_cost_model().choose_split_rows(len(rows), d=d)
    n_splits = (1 if split_rows is None
                else max(1, -(-len(rows) // int(split_rows))))
    return run_jobs_streaming(jobs, ArraySplits(items, n_splits=n_splits),
                              mesh=mesh, engine=engine, combiner=None,
                              prefetch=0)


def run_job(job: MapReduceJob, items, *, mesh=None, engine: str = "auto",
            split_rows=None) -> JobResult:
    """Execute one job end-to-end. -> JobResult(output, stats)."""
    return run_jobs([job], items, mesh=mesh, engine=engine,
                    split_rows=split_rows)[0]
