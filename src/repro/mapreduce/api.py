"""Legacy mesh-MapReduce surface, now thin shims over the Job API.

The original hard-coded pipeline (``bucket_by_zone`` with a
``compress_coords`` boolean + ``sharded_zone_reduce``) is kept for backward
compatibility; both delegate to the host-engine stages in
``mapreduce/job.py`` (``shuffle_stage`` / ``reduce_stage``) — the same
stages the split-streaming executor (``mapreduce/executor.py``) now runs
per split — with the codec chosen from the registry in
``mapreduce/codecs.py``. New code should build a ``MapReduceJob`` and call
``run_job``/``run_jobs`` (or ``run_job_streaming`` over a ``SplitSource``
for out-of-core catalogs) instead.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.mapreduce.instrumentation import StageStats
from repro.mapreduce.job import Reducer, ShuffledData, reduce_stage, shuffle_stage


@dataclasses.dataclass
class ZonedData:
    owned: np.ndarray          # [Z, C1, 3] float32 (zero-padded)
    bucket: np.ndarray         # [Z, C2, 3] float32 (owned + borders, zero-padded)
    n_owned: np.ndarray        # [Z] int32 real counts
    zone_height: float
    radius: float
    shuffle_bytes: int         # bytes that crossed the shuffle (for the benches)


def bucket_by_zone(xyz: np.ndarray, radius: float, *, zone_height: float = 0.0,
                   tile: int = 256, compress_coords: bool = False,
                   pad_zones_to: int = 1) -> ZonedData:
    """Map + shuffle via the Job API's ``shuffle_stage`` with a
    ``ZonePartitioner``; ``compress_coords`` selects the int16 codec (the
    LZO analogue). zone_height defaults to the radius (paper's choice)."""
    from repro.mapreduce.zones import ZonePartitioner
    part = ZonePartitioner(radius, zone_height)
    stats = StageStats()
    sd = shuffle_stage(xyz, part, "int16" if compress_coords else "identity",
                       tile=tile, pad_partitions_to=pad_zones_to, stats=stats)
    return ZonedData(sd.owned, sd.bucket, sd.n_owned, part.height, radius,
                     stats.shuffle_wire_bytes)


class _FnReducer(Reducer):
    def __init__(self, fn):
        self._fn = fn

    def per_partition(self, owned_p, bucket_p):
        return self._fn(owned_p, bucket_p)


def sharded_zone_reduce(per_zone_fn, zd: ZonedData, mesh=None):
    """Apply ``per_zone_fn(owned_z, bucket_z) -> array`` over all zones,
    sharded over the mesh's data axis when given, and sum the results."""
    sd = ShuffledData(owned=np.asarray(zd.owned), bucket=np.asarray(zd.bucket),
                      n_owned=np.asarray(zd.n_owned),
                      n_bucket=np.zeros(len(zd.n_owned), np.int32))
    return reduce_stage([_FnReducer(per_zone_fn)], sd, mesh)[0]
