"""Minimal mesh MapReduce: zone bucketing (map+shuffle) and sharded reduce.

Mirrors the paper's Hadoop structure:
- *map*: assign each catalog point a zone key; emit border copies so every zone
  bucket is self-contained (the paper's mappers "copy objects within a certain
  region around each block"),
- *shuffle*: bucket-by-key into fixed-capacity padded arrays (host-side, like the
  sort/spill phase). Optional int16 coordinate compression = the LZO analogue.
- *reduce*: per-zone pair kernels over the mesh (shard_map over the data axis),
  combined with psum (the paper's second, trivial MapReduce step).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import sky


@dataclasses.dataclass
class ZonedData:
    owned: np.ndarray          # [Z, C1, 3] float32 (zero-padded)
    bucket: np.ndarray         # [Z, C2, 3] float32 (owned + borders, zero-padded)
    n_owned: np.ndarray        # [Z] int32 real counts
    zone_height: float
    radius: float
    shuffle_bytes: int         # bytes that crossed the shuffle (for the benches)


def _pad_to(x: np.ndarray, n: int) -> np.ndarray:
    out = np.zeros((n, x.shape[1]), x.dtype)
    out[:len(x)] = x
    return out


def _round_up(x: int, m: int) -> int:
    return max(m, ((x + m - 1) // m) * m)


def bucket_by_zone(xyz: np.ndarray, radius: float, *, zone_height: float = 0.0,
                   tile: int = 256, compress_coords: bool = False,
                   pad_zones_to: int = 1) -> ZonedData:
    """Map + shuffle. zone_height defaults to the radius (paper's choice: favor
    larger blocks; border copies then come only from adjacent zones)."""
    h = zone_height or max(radius, 1e-4)
    Z = sky.n_zones(h)
    Z = _round_up(Z, pad_zones_to)
    dec = sky.dec_of(xyz)
    z = np.clip(((dec + np.pi / 2) / h).astype(np.int32), 0, Z - 1)

    if compress_coords:
        # int16 shuffle payload (the LZO trade: fewer bytes, cheap codec)
        q = np.clip(np.round(xyz * 32767.0), -32767, 32767).astype(np.int16)
        xyz_s = (q.astype(np.float32) / 32767.0)
        payload_bytes_per_point = 6
    else:
        xyz_s = xyz.astype(np.float32)
        payload_bytes_per_point = 12

    owned_lists = [xyz_s[z == k] for k in range(Z)]
    # border copies: a point within `radius` of a zone boundary is replicated into
    # the adjacent zone's bucket
    lo_border = (dec - (z * h - np.pi / 2)) <= radius          # near lower edge
    hi_border = (((z + 1) * h - np.pi / 2) - dec) <= radius    # near upper edge
    bucket_lists = []
    for k in range(Z):
        parts = [owned_lists[k]]
        if k > 0:
            parts.append(xyz_s[(z == k - 1) & hi_border])
        if k + 1 < Z:
            parts.append(xyz_s[(z == k + 1) & lo_border])
        bucket_lists.append(np.concatenate(parts, axis=0) if parts else
                            np.zeros((0, 3), np.float32))

    C1 = _round_up(max(len(o) for o in owned_lists), tile)
    C2 = _round_up(max(len(b) for b in bucket_lists), tile)
    owned = np.stack([_pad_to(o, C1) for o in owned_lists])
    bucket = np.stack([_pad_to(b, C2) for b in bucket_lists])
    n_owned = np.array([len(o) for o in owned_lists], np.int32)
    shuffle_bytes = int(sum(len(b) for b in bucket_lists)) * payload_bytes_per_point
    return ZonedData(owned, bucket, n_owned, h, radius, shuffle_bytes)


def sharded_zone_reduce(per_zone_fn, zd: ZonedData, mesh=None):
    """Apply ``per_zone_fn(owned_z, bucket_z) -> array`` over all zones, sharded over
    the mesh's data axis when given, and sum the results."""
    owned = jnp.asarray(zd.owned)
    bucket = jnp.asarray(zd.bucket)
    if mesh is None or "data" not in mesh.axis_names or mesh.shape["data"] == 1:
        out = jax.lax.map(lambda ab: per_zone_fn(ab[0], ab[1]), (owned, bucket))
        return jnp.sum(out, axis=0)

    from jax.sharding import PartitionSpec as P

    def body(o, b):
        r = jax.lax.map(lambda ab: per_zone_fn(ab[0], ab[1]), (o, b))
        return jax.lax.psum(jnp.sum(r, axis=0), "data")

    Z = owned.shape[0]
    assert Z % mesh.shape["data"] == 0, (Z, mesh.shape)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P("data", None, None), P("data", None, None)),
        out_specs=P(),
        axis_names=frozenset({"data"}),
        check_vma=False,
    )(owned, bucket)
