"""Per-stage accounting for MapReduce jobs (the paper's Table 4, per job).

The paper instruments each Hadoop task (map / shuffle / reduce) for
instruction rate vs disk and network I/O and derives Amdahl numbers to name
the bottleneck resource. ``StageStats`` is the per-job analogue: every
``MapReduceJob`` run fills one, and ``roofline()`` recasts it as
``core.amdahl.RooflineTerms`` so the same AD / ADN / dominant-resource
analysis falls out of *any* job — not just the two hard-coded paper apps.

Stage -> resource mapping:
- map + reduce bytes  -> the memory term (HBM analogue of the paper's disk),
- shuffle wire bytes  -> the collective term (the paper's network),
- reduce FLOPs        -> the compute term.

Streaming runs (``mapreduce/executor.py``) add a fourth boundary: splits are
fetched and transferred while earlier splits compute, so split I/O divides
into *exposed* time (``fetch_wall_s``, the executor actually blocked — part
of ``wall_s``) and *hidden* time (``overlap_hidden_s``, prefetch work that
ran under compute and cost nothing) — the Amdahl tables can then separate
what streaming hides from what it merely relabels. ``splits`` keeps one
record per split for straggler analysis.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.amdahl import RooflineTerms


@dataclasses.dataclass
class StageStats:
    """Bytes, FLOPs, and wall time per MapReduce stage of one job run."""

    job: str = ""
    codec: str = "identity"
    engine: str = "host"               # which engine ran: "host" | "device"
    n_items: int = 0
    n_partitions: int = 0
    n_shards: int = 1                  # mesh data-axis size the reduce ran over
    # map: key assignment + border replication
    map_wall_s: float = 0.0
    map_bytes: int = 0                 # input bytes read by the mappers
    # shuffle: encode -> wire -> decode -> pad/stack. Walls are fenced with
    # block_until_ready, so device stages report device time, not dispatch.
    shuffle_wall_s: float = 0.0
    shuffle_wire_bytes: int = 0        # bytes that crossed the shuffle
    shuffle_raw_bytes: int = 0         # float32-equivalent (compression baseline)
    shuffle_index_impl: str = ""       # resolved index path: "jnp"|"host"|"numpy"
    # reduce: per-partition kernels + combine
    reduce_wall_s: float = 0.0
    reduce_flops: float = 0.0
    reduce_bytes: int = 0              # bytes streamed by the reduce kernels
    reduce_padded_ratio: float = 1.0   # padded / real pair cells (capacity waste)
    # per-shard padded/real pair-cell ratios, length n_shards (a shard of
    # pure phantom padding shows its full padded cell count — load imbalance
    # and phantom waste in one vector; empty () off the MapReduce engines)
    shard_padded_ratio: tuple = ()
    # streaming (split) execution: one record per split plus the
    # exposed-vs-hidden split I/O decomposition
    n_splits: int = 1
    combiner: str = ""                 # active map-side combiner ("" = none)
    fetch_wall_s: float = 0.0          # split fetch/transfer the run WAITED on
    combine_wall_s: float = 0.0        # cross-split combine of partials
    overlap_hidden_s: float = 0.0      # prefetch work hidden under compute
    splits: tuple = ()                 # per-split record dicts (see executor)
    # external shuffle (disk spill): wire streams written to / read back from
    # the spill store when the accumulated mapped splits exceed the budget.
    # spill_wall_s is the EXPOSED spill I/O (flush waits + read-back waits
    # the executor actually blocked on; async write time hidden under map
    # compute lands in overlap_hidden_s like any other hidden I/O)
    spill_bytes: int = 0               # wire bytes written to spill segments
    spill_wall_s: float = 0.0          # exposed spill write + read-back wall
    spilled_splits: int = 0            # splits whose streams went to disk
    spill_peak_bytes: int = 0          # max resident wire bytes observed
    spill_chunk_bytes: int = 0         # largest single spill chunk written
    spill_ranges: int = 0              # partition ranges streamed back
    # lane execution (concurrent splits + speculative re-execution): with
    # n_lanes > 1 the per-stage walls above are SUMS over lanes that ran
    # concurrently, so ``elapsed_s`` carries the true end-to-end wall
    n_lanes: int = 1
    elapsed_s: float = 0.0             # measured end-to-end wall (0 = wall_s)
    speculated: int = 0                # clone dispatches the policy triggered
    clone_wins: int = 0                # splits where the clone finished first
    retries: int = 0                   # transient-fault re-dispatches
    lane_walls: tuple = ()             # per-lane busy seconds, length n_lanes
    # cost-model accounting (core/cost_model.py): the predicted stage walls
    # recorded alongside the measured ones, so model error is observable in
    # every bench row, and the tile the model resolved when tile="auto"
    predicted_shuffle_wall_s: float = 0.0
    predicted_reduce_wall_s: float = 0.0
    auto_tile: int = 0                 # 0 = tile was not auto-planned
    # energy accounting (obs/energy.py): joules per stage, measured (RAPL/
    # NVML counter deltas spread by active-wall share) or modeled
    # (PowerProfile watts x stage wall). All zero when metering is off.
    energy_j: float = 0.0              # total joules attributed to this run
    map_energy_j: float = 0.0
    shuffle_energy_j: float = 0.0
    reduce_energy_j: float = 0.0
    fetch_energy_j: float = 0.0
    combine_energy_j: float = 0.0
    spill_energy_j: float = 0.0
    energy_source: str = ""            # "" off | "modeled:<profile>" | "rapl" | "nvml"

    # per-stage accumulator fields that add across per-split / per-lane
    # partial StageStats when lanes merge their local stats into the shared one
    _ACCUM_FIELDS = ("n_items", "map_wall_s", "map_bytes", "shuffle_wall_s",
                     "shuffle_wire_bytes", "shuffle_raw_bytes",
                     "reduce_wall_s", "reduce_flops", "reduce_bytes",
                     "fetch_wall_s", "combine_wall_s", "overlap_hidden_s",
                     "spill_bytes", "spill_wall_s", "spilled_splits",
                     "speculated", "clone_wins", "retries",
                     "predicted_shuffle_wall_s", "predicted_reduce_wall_s",
                     "energy_j", "map_energy_j", "shuffle_energy_j",
                     "reduce_energy_j", "fetch_energy_j", "combine_energy_j",
                     "spill_energy_j")

    def merge_from(self, other: "StageStats") -> "StageStats":
        """Fold a per-split/per-lane partial ``StageStats`` into this one:
        accumulator fields add; identity fields (codec, engine, partition
        geometry, index impl) adopt the partial's value when unset here.
        Lanes each fill a private partial and commit it under the pool lock,
        so concurrent lanes never mutate the shared stats mid-stage."""
        for f in self._ACCUM_FIELDS:
            setattr(self, f, getattr(self, f) + getattr(other, f))
        for f in ("n_partitions", "n_shards", "shuffle_index_impl",
                  "auto_tile", "energy_source"):
            mine = getattr(self, f)
            if mine in (0, 1, ""):
                setattr(self, f, getattr(other, f))
        return self

    @property
    def prediction_error(self) -> float:
        """Worst predicted-vs-actual stage-wall ratio, folded to >= 1.0
        (a 2.0 means the cost model was off by 2x in either direction on
        some stage); 0.0 when no prediction was recorded."""
        errs = [max(p / a, a / p) for p, a in
                ((self.predicted_shuffle_wall_s, self.shuffle_wall_s),
                 (self.predicted_reduce_wall_s, self.reduce_wall_s))
                if p > 0.0 and a > 0.0]
        return max(errs) if errs else 0.0

    @property
    def wall_s(self) -> float:
        return (self.map_wall_s + self.shuffle_wall_s + self.reduce_wall_s
                + self.fetch_wall_s + self.combine_wall_s
                + self.spill_wall_s)

    @property
    def run_wall_s(self) -> float:
        """The run's true end-to-end wall: the measured elapsed time when
        lanes ran splits concurrently (stage walls then sum ACROSS lanes and
        over-count), else the stage-wall sum."""
        return self.elapsed_s if self.elapsed_s > 0 else self.wall_s

    @property
    def overlap_fraction(self) -> float:
        """Fraction of total split-I/O time hidden under compute (1.0 =
        perfectly overlapped, 0.0 = fully exposed or not streaming)."""
        total = self.overlap_hidden_s + self.fetch_wall_s
        return self.overlap_hidden_s / total if total > 0 else 0.0

    @property
    def rows_per_joule(self) -> float:
        """Work per joule — the paper's energy-efficiency unit (its 7.7x /
        3.4x ratios are this number, blade over cluster). 0.0 when no
        metering was active."""
        return self.n_items / self.energy_j if self.energy_j > 0 else 0.0

    @property
    def compression_ratio(self) -> float:
        """Raw/wire shuffle bytes (1.0 = identity, 2.0 = int16, ~4 = int8)."""
        if not self.shuffle_wire_bytes:
            return 1.0
        return self.shuffle_raw_bytes / self.shuffle_wire_bytes

    @property
    def dominant_stage(self) -> str:
        """Which stage dominated wall time (the paper's per-task breakdown)."""
        times = {"map": self.map_wall_s, "shuffle": self.shuffle_wall_s,
                 "reduce": self.reduce_wall_s, "fetch": self.fetch_wall_s,
                 "combine": self.combine_wall_s, "spill": self.spill_wall_s}
        return max(times, key=times.get)

    def roofline(self, chips: int = 1, chip_w: float = 0.0) -> RooflineTerms:
        """Recast as three-resource roofline terms (Amdahl-number analysis).
        Spilled bytes cross the memory boundary twice (write + read back),
        the paper's disk term folded into the HBM analogue. Pass ``chip_w``
        (watts per chip, e.g. a ``PowerProfile.compute_w``) to get the
        balance point in watts as well as chips."""
        return RooflineTerms.from_stage_bytes(
            flops=self.reduce_flops,
            hbm_bytes=self.map_bytes + self.reduce_bytes
            + 2 * self.spill_bytes,
            wire_bytes=self.shuffle_wire_bytes,
            chips=chips, chip_w=chip_w)

    def to_dict(self, chips: int = 1) -> dict:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)}
        d.update(wall_s=self.wall_s, dominant_stage=self.dominant_stage,
                 compression_ratio=self.compression_ratio,
                 overlap_fraction=self.overlap_fraction,
                 prediction_error=self.prediction_error,
                 rows_per_joule=self.rows_per_joule)
        d["amdahl"] = self.roofline(chips).to_dict()
        return d


@dataclasses.dataclass
class RequestStats:
    """Per-request latency accounting for the MapReduce query service
    (``serving/mr_service.py``) — the request-level twin of the per-run
    ``StageStats``: how long the request waited in the submit queue, which
    micro-batch admitted it, and the wall of that batch's fused reduce.
    One batch serves many requests, so ``batch_wall_s`` repeats across the
    batch's members while ``queue_wait_s``/``latency_s`` are per-request."""

    rid: int = -1
    job: str = ""
    catalog: str = ""
    batch_index: int = -1       # micro-batch that served this request
    batch_size: int = 0         # requests admitted into that batch
    n_unique: int = 0           # distinct jobs the batch ran after coalescing
    t_submit_s: float = 0.0     # service-clock submit time
    queue_wait_s: float = 0.0   # submit -> admitted into a micro-batch
    batch_wall_s: float = 0.0   # the admitting batch's end-to-end wall
    latency_s: float = 0.0      # submit -> result ready

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def latency_summary(requests) -> dict:
    """Aggregate a stream of ``RequestStats`` into service-level numbers:
    queries/s over the observed span plus p50/p99 latency and queue wait —
    the latency-vs-throughput trade the admission window buys (the paper's
    consolidation question, asked of tails instead of means)."""
    reqs = list(requests)
    if not reqs:
        return {"n": 0, "span_s": 0.0, "qps": 0.0, "p50_ms": 0.0,
                "p99_ms": 0.0, "wait_p50_ms": 0.0, "wait_p99_ms": 0.0,
                "mean_batch": 0.0}
    lat = np.array([r.latency_s for r in reqs])
    wait = np.array([r.queue_wait_s for r in reqs])
    t0 = min(r.t_submit_s for r in reqs)
    span = max(r.t_submit_s + r.latency_s for r in reqs) - t0
    # A single request (or simultaneous zero-latency ones) spans ~0 s;
    # dividing by a floored span would report ~1e9 qps. A degenerate span
    # carries no throughput information, so report qps = 0 and let the
    # caller read span_s.
    qps = len(reqs) / span if span > 1e-9 else 0.0
    return {
        "n": len(reqs),
        "span_s": float(span),
        "qps": qps,
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "wait_p50_ms": float(np.percentile(wait, 50)) * 1e3,
        "wait_p99_ms": float(np.percentile(wait, 99)) * 1e3,
        "mean_batch": float(np.mean([r.batch_size for r in reqs])),
    }
