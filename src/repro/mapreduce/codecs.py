"""Shuffle codecs: pluggable wire formats for the shuffle stage.

The paper's LZO result generalizes: on a node whose bottleneck resource also
pays for I/O, shrinking the bytes that transit the shuffle is a win even when
the codec costs compute. This module unifies the two compression tricks that
previously lived in separate corners of the repo —

- the int16 coordinate trick from the old ``mapreduce/api.py`` shuffle
  (``compress_coords=True``), and
- the int8 block-quantizer from ``core/compression.py`` (the gradient-sync
  codec),

behind one ``ShuffleCodec`` encode/decode interface with explicit
``wire_bytes`` accounting, looked up by name in a registry. A
``MapReduceJob`` names its codec; the engine never special-cases one.

Contract (property-checked in ``tests/test_mapreduce_job.py``):
- ``decode(encode(x))`` round-trips within ``error_bound(x)`` elementwise,
- ``encode(x).wire_bytes == nbytes(x.size)`` — the static accounting formula
  and the actual payload agree, so ``StageStats.shuffle_wire_bytes`` can be
  computed per-bucket without materializing per-bucket payloads.

Device side (the ``engine="device"`` hot path in ``job.py``): every codec also
provides jax transforms ``encode_device(x) -> wire arrays`` and
``decode_device(*wire) -> float32``, so the shuffle can scatter payloads in
the *wire dtype* (int16/int8) and fuse the decode into the jitted reduce —
shuffle traffic then actually shrinks with the codec ratio instead of only
being counted smaller. ``identity``/``int16`` device transforms are bit-exact
matches of the host encode/decode; ``int8`` trades the host path's
cross-row block scales for per-row scales (same error bound, but a
row-independent layout the scatter can move), so its device results differ
from the host path within ``error_bound``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class EncodedShuffle:
    """A shuffle payload as it would cross the wire."""
    codec: str
    arrays: tuple                 # wire arrays (dtype = wire format)
    shape: tuple                  # original logical shape
    wire_bytes: int


class ShuffleCodec:
    """Interface: encode/decode + byte accounting. Subclass and register."""

    name: str = "base"
    exact: bool = False        # True iff decode(encode(x)) == x bit-for-bit

    def nbytes(self, n_elements: int) -> int:
        """Wire bytes for a payload of ``n_elements`` scalars."""
        raise NotImplementedError

    def error_bound(self, x: np.ndarray) -> float:
        """Max elementwise |x - decode(encode(x))| for in-domain inputs."""
        raise NotImplementedError

    def encode(self, x: np.ndarray) -> EncodedShuffle:
        raise NotImplementedError

    def decode(self, enc: EncodedShuffle) -> np.ndarray:
        raise NotImplementedError

    def roundtrip(self, x: np.ndarray) -> np.ndarray:
        """What the reducers see after the payload crosses the shuffle."""
        if self.exact:
            return np.asarray(x, np.float32)   # skip the no-op wire trip
        return self.decode(self.encode(np.asarray(x, np.float32)))

    # -- device (jax) transforms: the engine="device" wire format ----------
    # encode_device returns a tuple of arrays whose leading axis is the item
    # axis; the shuffle scatters each of them, and decode_device runs inside
    # the jitted reduce (works on any [..., d] wire layout).

    def encode_device(self, x):
        raise NotImplementedError

    def decode_device(self, *wire):
        raise NotImplementedError

    def device_bytes_per_item(self, d: int) -> int:
        """Wire bytes one [d]-item row occupies on the device shuffle."""
        import jax.numpy as jnp
        wire = self.encode_device(jnp.zeros((1, d), jnp.float32))
        return sum(int(np.prod(w.shape[1:])) * w.dtype.itemsize for w in wire)


class IdentityCodec(ShuffleCodec):
    """float32 passthrough — the uncompressed-shuffle baseline."""

    name = "identity"
    exact = True

    def nbytes(self, n_elements: int) -> int:
        return 4 * n_elements

    def error_bound(self, x) -> float:
        return 0.0

    def encode(self, x):
        x = np.asarray(x, np.float32)
        return EncodedShuffle(self.name, (x,), x.shape, x.nbytes)

    def decode(self, enc):
        return enc.arrays[0].reshape(enc.shape)

    def encode_device(self, x):
        import jax.numpy as jnp
        return (jnp.asarray(x, jnp.float32),)

    def decode_device(self, *wire):
        return wire[0]


class Int16Codec(ShuffleCodec):
    """Fixed-point int16 over the domain [-max_abs, max_abs] (2x smaller).

    ``max_abs=1.0`` is exactly the old ``compress_coords=True`` coordinate
    trick (unit-sphere catalogs). Other domains parameterize ``max_abs``;
    integer-valued payloads with ``max_abs < 32767`` survive a round() on the
    reduce side losslessly (used by the wordcount job).
    """

    name = "int16"

    def __init__(self, max_abs: float = 1.0):
        self.max_abs = float(max_abs)

    def nbytes(self, n_elements: int) -> int:
        return 2 * n_elements

    def error_bound(self, x) -> float:
        return self.max_abs / 32767.0

    def encode(self, x):
        x = np.asarray(x, np.float32)
        q = np.clip(np.round(x * (32767.0 / self.max_abs)),
                    -32767, 32767).astype(np.int16)
        return EncodedShuffle(self.name, (q,), x.shape, q.nbytes)

    def decode(self, enc):
        return (enc.arrays[0].astype(np.float32) *
                (self.max_abs / 32767.0)).reshape(enc.shape)

    def encode_device(self, x):
        import jax.numpy as jnp
        q = jnp.clip(jnp.round(x * (32767.0 / self.max_abs)),
                     -32767, 32767).astype(jnp.int16)
        return (q,)

    def decode_device(self, *wire):
        import jax.numpy as jnp
        return wire[0].astype(jnp.float32) * (self.max_abs / 32767.0)


class Int8BlockCodec(ShuffleCodec):
    """Block-wise int8 with per-block fp32 max-abs scales (~4x smaller).

    Reuses ``core/compression.py``'s quantizer — the same codec the compressed
    gradient all-reduce uses — so the shuffle and the collective share one wire
    format and one set of tests. Scale-free: handles any dynamic range.
    """

    name = "int8"

    def __init__(self, block: int = 0):
        from repro.core import compression
        self.block = int(block) or compression.BLOCK

    def nbytes(self, n_elements: int) -> int:
        from repro.core.compression import int8_wire_bytes
        return int8_wire_bytes(n_elements, self.block)

    def error_bound(self, x) -> float:
        x = np.asarray(x, np.float32)
        return (float(np.max(np.abs(x))) / 127.0) if x.size else 0.0

    def encode(self, x):
        from repro.core.compression import quantize_block
        x = np.asarray(x, np.float32)
        q, scale, _ = quantize_block(x.reshape(-1), self.block)
        q, scale = np.asarray(q), np.asarray(scale, np.float32)
        return EncodedShuffle(self.name, (q, scale), x.shape,
                              self.nbytes(x.size))

    def decode(self, enc):
        from repro.core.compression import dequantize_block
        q, scale = enc.arrays
        n = int(np.prod(enc.shape)) if enc.shape else 1
        flat = np.asarray(dequantize_block(q, scale, n, block=self.block))
        return flat.reshape(enc.shape)

    # Device layout: per-ROW max-abs scales (one fp32 scale per item), so the
    # shuffle can scatter rows independently of any cross-row block structure.
    # Same 1/127 relative error bound as the host block codec; results differ
    # from the host path within error_bound (documented, tested).

    def encode_device(self, x):
        import jax.numpy as jnp
        scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127
                     ).astype(jnp.int8)
        return (q, scale.astype(jnp.float32))

    def decode_device(self, *wire):
        import jax.numpy as jnp
        q, scale = wire
        return q.astype(jnp.float32) * scale[..., None]


_REGISTRY: dict[str, ShuffleCodec] = {}


def register_codec(codec: ShuffleCodec, *, overwrite: bool = False) -> ShuffleCodec:
    """Add a codec instance to the registry under ``codec.name``."""
    if codec.name in _REGISTRY and not overwrite:
        raise ValueError(f"codec {codec.name!r} already registered")
    _REGISTRY[codec.name] = codec
    return codec


def get_codec(codec: str | ShuffleCodec) -> ShuffleCodec:
    """Resolve a codec by registry name (instances pass through)."""
    if isinstance(codec, ShuffleCodec):
        return codec
    try:
        return _REGISTRY[codec]
    except KeyError:
        raise KeyError(f"unknown shuffle codec {codec!r}; "
                       f"available: {available_codecs()}") from None


def available_codecs() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_codec(IdentityCodec())
register_codec(Int16Codec())
register_codec(Int8BlockCodec())
