"""Neighbor Statistics (the paper's compute-intensive app): pair-distance histogram.

Same map/shuffle as Neighbor Searching; reducers emit per-zone cumulative counts per
angular edge (theta in {1..60 arcsec} by default), the combine step (the paper's second
trivial MapReduce) psums and differentiates the cumulative counts.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.data.sky import ARCSEC
from repro.kernels.zones_pairs.ops import pair_hist
from repro.mapreduce.api import bucket_by_zone, sharded_zone_reduce


def neighbor_statistics(xyz: np.ndarray, *, edges_arcsec=None, mesh=None,
                        compress_coords: bool = False,
                        use_pallas: bool | None = None,
                        tile: int = 256) -> np.ndarray:
    """-> histogram over (0, e1], (e1, e2], ... in arcsec (unordered pairs)."""
    if edges_arcsec is None:
        edges_arcsec = np.arange(1, 61, dtype=np.float64)
    edges_rad = np.asarray(edges_arcsec, np.float64) * ARCSEC
    radius = float(edges_rad[-1])
    pad_z = (mesh.shape["data"] if mesh is not None and
             "data" in mesh.axis_names else 1)
    zd = bucket_by_zone(xyz, radius, tile=tile,
                        compress_coords=compress_coords, pad_zones_to=pad_z)
    cos_edges = jnp.asarray(np.cos(edges_rad), jnp.float32)

    def per_zone(owned_z, bucket_z):
        return pair_hist(owned_z, bucket_z, cos_edges, use_pallas=use_pallas)

    cum = np.asarray(sharded_zone_reduce(per_zone, zd, mesh)).astype(np.int64)
    cum -= int(zd.n_owned.sum())          # self pairs (theta=0) hit every edge
    cum //= 2                             # each unordered pair seen twice
    hist = np.diff(np.concatenate([[0], cum]))
    return hist
