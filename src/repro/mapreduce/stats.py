"""Neighbor Statistics (the paper's compute-intensive app) as a MapReduce job.

Same map/shuffle stages as Neighbor Searching (shared via ``ZonePartitioner``
— batch both apps over one shuffle with ``run_jobs``); the reducer emits
per-zone cumulative counts per angular edge, and ``finalize`` (the paper's
second, trivial MapReduce) removes self pairs, halves the double count, and
differentiates the cumulative counts into a histogram.

``neighbor_statistics`` keeps the original signature as a deprecated wrapper
over ``neighbor_statistics_job`` + ``run_job``.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np

from repro.data.sky import ARCSEC
from repro.kernels.zones_pairs.ops import pair_hist, pair_hist_masked
from repro.mapreduce.job import MapReduceJob, Reducer, ShuffledData, run_job
from repro.mapreduce.zones import ZonePartitioner

DEFAULT_EDGES_ARCSEC = tuple(float(e) for e in range(1, 61))


@dataclasses.dataclass(frozen=True)
class PairHistReducer(Reducer):
    """Cumulative per-edge pair counts per zone; finalize differentiates."""

    edges_rad: tuple
    use_pallas: bool | None = None

    def _cos_edges(self):
        return jnp.asarray(np.cos(np.asarray(self.edges_rad)), jnp.float32)

    def per_partition(self, owned_p, bucket_p):
        return pair_hist(owned_p, bucket_p, self._cos_edges(),
                         use_pallas=self.use_pallas)

    def reduce_partitions(self, owned, bucket, n_owned, n_bucket):
        return pair_hist_masked(owned, bucket, n_owned, n_bucket,
                                self._cos_edges(),
                                use_pallas=self.use_pallas)

    def reduce_traceable(self):
        from repro.kernels.zones_pairs.ops import masked_uses_pallas
        return masked_uses_pallas(self.use_pallas)

    def finalize(self, total, sd: ShuffledData):
        cum = np.asarray(total).astype(np.int64)
        cum -= int(sd.n_owned.sum())   # self pairs (theta=0) hit every edge
        cum //= 2                      # each unordered pair seen twice
        return np.diff(np.concatenate([[0], cum]))

    def flops(self, sd: ShuffledData):
        return sd.pair_cells * (6.0 + len(self.edges_rad))


def neighbor_statistics_job(edges_arcsec=None, *, codec="identity",
                            tile: int = 256,
                            use_pallas: bool | None = None,
                            partitioner: ZonePartitioner | None = None,
                            ) -> MapReduceJob:
    """The Neighbor Statistics app as a composable job. The partition radius
    is the largest edge; pass a shared ``partitioner`` to batch with the
    search job over one shuffle."""
    if edges_arcsec is None:
        edges_arcsec = DEFAULT_EDGES_ARCSEC
    edges_rad = tuple(float(e) * ARCSEC for e in np.asarray(edges_arcsec))
    part = partitioner or ZonePartitioner(edges_rad[-1])
    return MapReduceJob("neighbor_statistics", part,
                        PairHistReducer(edges_rad, use_pallas),
                        codec=codec, tile=tile)


def neighbor_statistics(xyz: np.ndarray, *, edges_arcsec=None, mesh=None,
                        compress_coords: bool = False,
                        use_pallas: bool | None = None,
                        tile: int = 256) -> np.ndarray:
    """Deprecated wrapper (use ``neighbor_statistics_job`` + ``run_job``):
    histogram over (0, e1], (e1, e2], ... in arcsec (unordered pairs)."""
    warnings.warn("neighbor_statistics is deprecated; build a job with "
                  "neighbor_statistics_job() and execute it with run_job()",
                  DeprecationWarning, stacklevel=2)
    job = neighbor_statistics_job(
        edges_arcsec, tile=tile, use_pallas=use_pallas,
        codec="int16" if compress_coords else "identity")
    return run_job(job, xyz, mesh=mesh).output
