"""Split-streaming MapReduce executor: the four-stage pipeline
map -> combine -> shuffle -> reduce over HDFS-block-analog catalog splits.

The paper's whole premise is streaming — Hadoop moves block-sized splits
through the pipeline and the win on low-power nodes comes from keeping
sequential I/O flowing while shrinking CPU cost per byte. This module makes
the engine that shape: a ``SplitSource`` (``data/pipeline.py``) feeds splits
one at a time, each split runs the SAME map/shuffle/reduce stages as the
monolithic path (``run_job(job, xyz)`` is literally the one-split case), and
two things keep memory and wall time bounded:

- **Map-side combine** (Hadoop's Combiner). A pluggable ``Combiner`` merges
  per-split partials on device, so only combined accumulators persist across
  splits: datasets larger than device memory stream at full engine speed.
  The default is derived from the ``Reducer`` (``Reducer.combiner()``) for
  commutative-monoid outputs — wordcount's token histogram pre-aggregates
  each split to (token, count) rows before the shuffle, cutting shuffle wire
  bytes by the split's duplication factor, exactly the paper's
  shrink-bytes-before-the-boundary move. Reducers whose kernels couple rows
  across items (pair counting: a pair can span two splits) have no valid
  combiner; their splits accumulate as wire-dtype ``MappedSplit`` streams
  (Hadoop's shuffle spill — the reduce starts when the last map ends) and
  one global reduce runs at the end. Bit-identical either way for exact
  codecs: bucket contents are the same multisets and partition reductions
  are commutative integer sums.

- **Transfer/compute overlap** (double buffering). A ``Prefetcher`` thread
  fetches, pre-combines, and ``jax.device_put``s split k+1 while split k is
  still being encoded/reduced on the main thread. ``StageStats`` splits the
  I/O into ``fetch_wall_s`` (exposed — the executor actually waited) and
  ``overlap_hidden_s`` (hidden under compute), plus a per-split record
  stream for straggler analysis (``ft/stragglers.py``).

``mesh=`` composes with streaming: each split (or the accumulated stream)
reduces through the psum-sharded tier path, and the cross-split combine
operates on the replicated partial.

    src = MemmapCatalogSplits("catalog.f32", d=3, rows_per_split=1 << 20)
    res = run_job_streaming(neighbor_search_job(0.02, codec="int16"), src)
    res.stats.overlap_fraction, res.stats.n_splits
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import Prefetcher, SplitSource  # noqa: F401
from repro.mapreduce.codecs import get_codec
from repro.mapreduce.instrumentation import StageStats
from repro.mapreduce.job import (JobResult, concat_mapped,
                                 host_shuffle_reduce, map_split_device,
                                 shuffle_reduce_device, validate_batch)


# ---------------------------------------------------------------------------
# Combiner: the pluggable map-side combine stage
# ---------------------------------------------------------------------------

class Combiner:
    """Hadoop's map-side combine as a pluggable stage.

    ``precombine`` runs on the raw split BEFORE map/shuffle (inside the
    prefetch thread, so it overlaps compute) and may rewrite the split into
    an equivalent, smaller item stream — that is where shuffle bytes
    actually shrink. ``combine`` merges per-split reduce partials on device;
    the base implementation is the commutative-monoid tree-sum, correct for
    any reducer whose totals add (all the stock reducers' accumulators are
    sums already — that is how partitions combine).

    A combiner is only VALID when reduce(split A + split B) equals
    combine(reduce(A), reduce(B)) — true for per-row folds like token
    counting, false for cross-row kernels like pair counting. The executor
    therefore derives defaults from ``Reducer.combiner()`` (None = no
    combine, accumulate the shuffle instead) rather than guessing.
    """

    name = "sum"

    def precombine(self, items: np.ndarray) -> np.ndarray:
        """Rewrite one raw split into an equivalent item stream (host side,
        runs in the prefetch thread). Default: unchanged."""
        return items

    def combine(self, acc, partials):
        """Merge a new tuple of per-job reduce partials into the running
        accumulator (device pytrees; ``acc`` is None on the first split)."""
        if acc is None:
            return partials
        return jax.tree.map(jnp.add, acc, partials)


@dataclasses.dataclass
class StreamSummary:
    """Aggregate post-shuffle state of a combine-mode streaming run — what
    ``Reducer.finalize`` sees instead of a materialized ``ShuffledData``.
    ``n_owned``/``n_bucket`` are per-partition counts SUMMED over splits, so
    count-based corrections (self-pair removal etc.) work unchanged."""

    n_owned: np.ndarray        # [P] int64
    n_bucket: np.ndarray       # [P] int64
    pair_cells: float = 0.0
    owned_cells: float = 0.0
    real_pair_cells: float = 0.0

    @property
    def padded_ratio(self) -> float:
        return (self.pair_cells / self.real_pair_cells
                if self.real_pair_cells else 1.0)


class _Agg:
    """Running padded/real cell + partition-count aggregation over splits."""

    def __init__(self):
        self.pair_pad = 0.0
        self.pair_real = 0.0
        self.owned_cells = 0.0
        self.shard_pad = None
        self.shard_real = None
        self.n_owned = None
        self.n_bucket = None

    def add(self, sd, shard_pad, shard_real):
        self.pair_pad += sd.pair_cells
        self.pair_real += sd.real_pair_cells
        self.owned_cells += sd.owned_cells
        no = np.asarray(sd.n_owned, np.int64)
        nb = np.asarray(sd.n_bucket, np.int64)
        if self.shard_pad is None:
            self.shard_pad = np.asarray(shard_pad, np.float64).copy()
            self.shard_real = np.asarray(shard_real, np.float64).copy()
            self.n_owned, self.n_bucket = no.copy(), nb.copy()
        else:
            self.shard_pad += shard_pad
            self.shard_real += shard_real
            self.n_owned += no
            self.n_bucket += nb

    def finish(self, stats: StageStats):
        stats.reduce_padded_ratio = (self.pair_pad / self.pair_real
                                     if self.pair_real else 1.0)
        if self.shard_pad is not None:
            stats.shard_padded_ratio = tuple(
                float(p / max(r, 1.0))
                for p, r in zip(self.shard_pad, self.shard_real))

    def summary(self) -> StreamSummary:
        return StreamSummary(self.n_owned, self.n_bucket,
                             pair_cells=self.pair_pad,
                             owned_cells=self.owned_cells,
                             real_pair_cells=self.pair_real)


def _resolve_combiner(combiner, jobs, codec):
    """None / "auto" / a ``Combiner`` instance -> the combiner to run (or
    None). "auto" derives from the reducers, and only engages when EVERY
    batched job provides one, they agree, and the codec is exact — a lossy
    codec quantizes the combiner's pre-aggregated counts into a different
    wire domain than the raw items, which would break streaming==monolithic
    parity silently. Pass an instance to force."""
    if combiner is None:
        return None
    if isinstance(combiner, Combiner):
        return combiner
    if combiner != "auto":
        raise ValueError(f"combiner must be None, 'auto', or a Combiner "
                         f"instance, got {combiner!r}")
    if not codec.exact:
        return None
    combs = [j.reducer.combiner() for j in jobs]
    if any(c is None for c in combs):
        return None
    if any(c != combs[0] for c in combs[1:]):
        return None
    return combs[0]


# ---------------------------------------------------------------------------
# The streaming executor
# ---------------------------------------------------------------------------

def run_jobs_streaming(jobs, source: SplitSource, *, mesh=None,
                       engine: str = "auto", combiner="auto",
                       prefetch: int = 2,
                       straggler_monitor=None) -> list[JobResult]:
    """Stream every split of ``source`` through map -> combine -> shuffle ->
    reduce and return one ``JobResult`` per job (all sharing one
    ``StageStats`` with per-split records).

    - ``combiner="auto"`` derives the map-side combine from the reducers
      (see ``_resolve_combiner``); ``None`` disables it (splits accumulate
      as wire-dtype streams, one global reduce at the end); a ``Combiner``
      instance forces it.
    - ``prefetch`` is the double-buffer depth: >0 fetches + device-transfers
      split k+1 on a background thread while split k computes
      (``overlap_hidden_s`` records what that hid); 0 runs synchronously
      (what ``run_jobs`` uses for its one-split delegate).
    - ``straggler_monitor`` (``ft.StragglerMonitor``) receives
      ``record(split_index, split_wall_s)`` per split, so slow splits can
      drive Hadoop-style speculative re-execution policy
      (``ft.SpeculativePolicy``).
    - ``mesh`` composes: per-split (or final) reduces run psum-sharded over
      the ``data`` axis; cross-split combine sees the replicated partial.

    The partition space must be split-independent (``n_partitions`` is read
    from the first split) — true for the stock zone/hash partitioners.
    """
    if not jobs:
        return []
    validate_batch(jobs)
    if engine == "auto":
        engine = "device"
    if engine not in ("device", "host"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "expected 'auto', 'device', or 'host'")
    j0 = jobs[0]
    codec = get_codec(j0.codec)
    part = j0.partitioner
    comb = _resolve_combiner(combiner, jobs, codec)
    K = int(source.n_splits())
    device = engine == "device"
    stats = StageStats(job="+".join(j.name for j in jobs), engine=engine,
                       codec=codec.name, n_splits=K,
                       combiner=comb.name if comb else "")

    def fetch(k):
        # -> (items, raw_rows, raw_bytes): the RAW split size is carried
        # alongside so n_items/map_bytes report what was actually fetched,
        # not the combiner's pre-aggregated rewrite
        s = source.split(k)
        raw_rows, raw_bytes = len(s), int(np.asarray(s).nbytes)
        if comb is not None:
            s = comb.precombine(s)
        return s, raw_rows, raw_bytes

    def fetch_to_device(k):
        # runs on the prefetch thread: host I/O, precombine, AND the
        # host->device transfer all overlap the main thread's compute
        s, raw_rows, raw_bytes = fetch(k)
        return (jax.device_put(np.ascontiguousarray(
            np.asarray(s, np.float32))), raw_rows, raw_bytes)

    def synchronous():
        for k in range(K):
            t0 = time.perf_counter()
            item = fetch(k)
            dt = time.perf_counter() - t0
            yield k, item, dt, dt

    acc = None
    mapped = []
    host_items = []
    recs = []
    agg = _Agg()
    raw_items_total = 0
    raw_bytes_total = 0
    P = None

    def consume(k, item, wait_s, prep_s):
        nonlocal acc, P, raw_items_total, raw_bytes_total
        items_k, raw_rows, raw_bytes = item
        raw_items_total += raw_rows
        raw_bytes_total += raw_bytes
        stats.fetch_wall_s += wait_s
        stats.overlap_hidden_s += max(prep_s - wait_s, 0.0)
        if P is None:
            P = int(part.n_partitions(items_k))
        rec = {"split": k, "n_items": raw_rows, "fetch_wait_s": wait_s,
               "fetch_prep_s": prep_s}
        m0, s0, r0 = stats.map_wall_s, stats.shuffle_wall_s, stats.reduce_wall_s
        if device:
            t0 = time.perf_counter()
            m = map_split_device(part, codec, items_k, P)
            stats.map_wall_s += time.perf_counter() - t0
            if comb is None:
                mapped.append(m)
            else:
                totals, sd, sp, sr = shuffle_reduce_device(jobs, m, P, stats,
                                                           mesh)
                agg.add(sd, sp, sr)
                t0 = time.perf_counter()
                acc = comb.combine(acc, totals)
                stats.combine_wall_s += time.perf_counter() - t0
        else:
            items_h = np.asarray(items_k)
            if comb is None:
                host_items.append(items_h)
            else:
                totals, sd, sp, sr = host_shuffle_reduce(jobs, items_h,
                                                         stats, mesh)
                agg.add(sd, sp, sr)
                t0 = time.perf_counter()
                acc = comb.combine(acc, totals)
                stats.combine_wall_s += time.perf_counter() - t0
        rec["map_s"] = stats.map_wall_s - m0
        rec["shuffle_s"] = stats.shuffle_wall_s - s0
        rec["reduce_s"] = stats.reduce_wall_s - r0
        # the split's own end-to-end cost: its fetch/transfer work (prep, as
        # measured in the producer whether or not it was hidden) plus its
        # processing walls. In accumulate mode processing is deferred to the
        # one global reduce, so per-split cost is I/O-dominated — exactly
        # the signal Hadoop's speculative execution watches (a split whose
        # read stalls shows up here even when other splits hid theirs).
        rec["wall_s"] = (prep_s + rec["map_s"] + rec["shuffle_s"]
                         + rec["reduce_s"])
        recs.append(rec)
        if straggler_monitor is not None:
            straggler_monitor.record(k, rec["wall_s"])

    if K > 1 and prefetch > 0:
        produce = fetch_to_device if device else fetch
        with Prefetcher(produce, depth=prefetch, n=K) as pf:
            while (got := pf.get()) is not None:
                consume(*got)
    else:
        for got in synchronous():
            consume(*got)
    assert len(recs) == K, (len(recs), K)

    if comb is None:
        # no valid map-side combine: the accumulated wire-format streams
        # cross ONE global shuffle+reduce (Hadoop's reduce-after-last-map)
        if device:
            totals, sd, sp, sr = shuffle_reduce_device(
                jobs, concat_mapped(mapped), P, stats, mesh)
        else:
            items_all = (host_items[0] if len(host_items) == 1
                         else np.concatenate(host_items, axis=0))
            totals, sd, sp, sr = host_shuffle_reduce(jobs, items_all, stats,
                                                     mesh)
        agg.add(sd, sp, sr)
        summary = sd
    else:
        t0 = time.perf_counter()
        totals = jax.block_until_ready(acc)
        stats.combine_wall_s += time.perf_counter() - t0
        summary = agg.summary()
    agg.finish(stats)
    # n_items/map_bytes always mean the RAW catalog (what the maps read) —
    # the per-split stages counted post-precombine rows when a combiner ran
    stats.n_items = raw_items_total
    stats.map_bytes = raw_bytes_total
    stats.splits = tuple(recs)
    return [JobResult(j.reducer.finalize(t, summary), stats)
            for j, t in zip(jobs, totals)]


def run_job_streaming(job, source: SplitSource, *, mesh=None,
                      engine: str = "auto", combiner="auto",
                      prefetch: int = 2, straggler_monitor=None) -> JobResult:
    """Stream one job over a ``SplitSource``. -> JobResult(output, stats)."""
    return run_jobs_streaming([job], source, mesh=mesh, engine=engine,
                              combiner=combiner, prefetch=prefetch,
                              straggler_monitor=straggler_monitor)[0]
