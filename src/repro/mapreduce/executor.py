"""Split-streaming MapReduce executor: the four-stage pipeline
map -> combine -> shuffle -> reduce over HDFS-block-analog catalog splits.

The paper's whole premise is streaming — Hadoop moves block-sized splits
through the pipeline and the win on low-power nodes comes from keeping
sequential I/O flowing while shrinking CPU cost per byte. This module makes
the engine that shape: a ``SplitSource`` (``data/pipeline.py``) feeds splits
one at a time, each split runs the SAME map/shuffle/reduce stages as the
monolithic path (``run_job(job, xyz)`` is literally the one-split case), and
two things keep memory and wall time bounded:

- **Map-side combine** (Hadoop's Combiner). A pluggable ``Combiner`` merges
  per-split partials on device, so only combined accumulators persist across
  splits: datasets larger than device memory stream at full engine speed.
  The default is derived from the ``Reducer`` (``Reducer.combiner()``) for
  commutative-monoid outputs — wordcount's token histogram pre-aggregates
  each split to (token, count) rows before the shuffle, cutting shuffle wire
  bytes by the split's duplication factor, exactly the paper's
  shrink-bytes-before-the-boundary move. Reducers whose kernels couple rows
  across items (pair counting: a pair can span two splits) have no valid
  combiner; their splits accumulate as wire-dtype ``MappedSplit`` streams
  (Hadoop's shuffle spill — the reduce starts when the last map ends) and
  one global reduce runs at the end. Bit-identical either way for exact
  codecs: bucket contents are the same multisets and partition reductions
  are commutative integer sums.

- **Transfer/compute overlap** (double buffering). A ``Prefetcher`` thread
  fetches, pre-combines, and ``jax.device_put``s split k+1 while split k is
  still being encoded/reduced on the main thread. ``StageStats`` splits the
  I/O into ``fetch_wall_s`` (exposed — the executor actually waited) and
  ``overlap_hidden_s`` (hidden under compute), plus a per-split record
  stream for straggler analysis (``ft/stragglers.py``).

``mesh=`` composes with streaming: each split (or the accumulated stream)
reduces through the psum-sharded tier path, and the cross-split combine
operates on the replicated partial.

- **Concurrent lanes + fault tolerance** (``n_lanes=``, ``speculate=``,
  ``max_retries=``, ``deadline_s=``, ``chaos=``). A ``LanePool`` dispatches
  independent splits to concurrent worker lanes (pinned one-per-device when
  several jax devices and no mesh are present) with Hadoop's reliability
  semantics made real: ``SpeculativePolicy`` verdicts clone the slow split
  onto a free lane and the first finisher commits (loser cancelled,
  buffers reclaimed, bit-identical by the same multiset/commutative-sum
  contracts), transient split failures retry with bounded backoff, a dead
  or wedged lane requeues its split on the survivors through the
  ``ft.Coordinator`` liveness machine, and ``deadline_s`` bounds the job.

    src = MemmapCatalogSplits("catalog.f32", d=3, rows_per_split=1 << 20)
    res = run_job_streaming(neighbor_search_job(0.02, codec="int16"), src)
    res.stats.overlap_fraction, res.stats.n_splits
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import itertools
import queue
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import Prefetcher, SplitSource  # noqa: F401
from repro.ft.chaos import CancelledFetch, LaneDeath, TransientSplitError
from repro.ft.coordinator import Coordinator, CoordinatorConfig
from repro.ft.stragglers import SpeculativePolicy
from repro.mapreduce.codecs import get_codec
from repro.mapreduce.instrumentation import StageStats
from repro.mapreduce.job import (JobResult, MappedSplit,  # noqa: F401
                                 StreamSummary, concat_mapped,
                                 host_shuffle_reduce, map_split_device,
                                 resolve_auto_job, shuffle_reduce_device,
                                 shuffle_reduce_device_streamed,
                                 validate_batch)
from repro.mapreduce.spill import (SpillConfig, SpillStore, mapped_to_host,
                                   mapped_wire_nbytes, plan_bounds)
from repro.obs.energy import get_meter
from repro.obs.trace import get_tracer


# ---------------------------------------------------------------------------
# Combiner: the pluggable map-side combine stage
# ---------------------------------------------------------------------------

class Combiner:
    """Hadoop's map-side combine as a pluggable stage.

    ``precombine`` runs on the raw split BEFORE map/shuffle (inside the
    prefetch thread, so it overlaps compute) and may rewrite the split into
    an equivalent, smaller item stream — that is where shuffle bytes
    actually shrink. ``combine`` merges per-split reduce partials on device;
    the base implementation is the commutative-monoid tree-sum, correct for
    any reducer whose totals add (all the stock reducers' accumulators are
    sums already — that is how partitions combine).

    A combiner is only VALID when reduce(split A + split B) equals
    combine(reduce(A), reduce(B)) — true for per-row folds like token
    counting, false for cross-row kernels like pair counting. The executor
    therefore derives defaults from ``Reducer.combiner()`` (None = no
    combine, accumulate the shuffle instead) rather than guessing.
    """

    name = "sum"

    def precombine(self, items: np.ndarray) -> np.ndarray:
        """Rewrite one raw split into an equivalent item stream (host side,
        runs in the prefetch thread). Default: unchanged."""
        return items

    def combine(self, acc, partials):
        """Merge a new tuple of per-job reduce partials into the running
        accumulator (device pytrees; ``acc`` is None on the first split)."""
        if acc is None:
            return partials
        return jax.tree.map(jnp.add, acc, partials)


class _Agg:
    """Running padded/real cell + partition-count aggregation over splits."""

    def __init__(self):
        self.pair_pad = 0.0
        self.pair_real = 0.0
        self.owned_cells = 0.0
        self.shard_pad = None
        self.shard_real = None
        self.n_owned = None
        self.n_bucket = None

    def add(self, sd, shard_pad, shard_real):
        self.pair_pad += sd.pair_cells
        self.pair_real += sd.real_pair_cells
        self.owned_cells += sd.owned_cells
        no = np.asarray(sd.n_owned, np.int64)
        nb = np.asarray(sd.n_bucket, np.int64)
        if self.shard_pad is None:
            self.shard_pad = np.asarray(shard_pad, np.float64).copy()
            self.shard_real = np.asarray(shard_real, np.float64).copy()
            self.n_owned, self.n_bucket = no.copy(), nb.copy()
        else:
            self.shard_pad += shard_pad
            self.shard_real += shard_real
            self.n_owned += no
            self.n_bucket += nb

    def finish(self, stats: StageStats):
        stats.reduce_padded_ratio = (self.pair_pad / self.pair_real
                                     if self.pair_real else 1.0)
        if self.shard_pad is not None:
            stats.shard_padded_ratio = tuple(
                float(p / max(r, 1.0))
                for p, r in zip(self.shard_pad, self.shard_real))

    def summary(self) -> StreamSummary:
        return StreamSummary(self.n_owned, self.n_bucket,
                             pair_cells=self.pair_pad,
                             owned_cells=self.owned_cells,
                             real_pair_cells=self.pair_real)


def _resolve_combiner(combiner, jobs, codec):
    """None / "auto" / a ``Combiner`` instance -> the combiner to run (or
    None). "auto" derives from the reducers, and only engages when EVERY
    batched job provides one, they agree, and the codec is exact — a lossy
    codec quantizes the combiner's pre-aggregated counts into a different
    wire domain than the raw items, which would break streaming==monolithic
    parity silently. Pass an instance to force."""
    if combiner is None:
        return None
    if isinstance(combiner, Combiner):
        return combiner
    if combiner != "auto":
        raise ValueError(f"combiner must be None, 'auto', or a Combiner "
                         f"instance, got {combiner!r}")
    if not codec.exact:
        return None
    combs = [j.reducer.combiner() for j in jobs]
    if any(c is None for c in combs):
        return None
    if any(c != combs[0] for c in combs[1:]):
        return None
    return combs[0]


# ---------------------------------------------------------------------------
# External shuffle: spill accumulated wire streams to disk, stream back
# ---------------------------------------------------------------------------

def _resolve_spill(spill) -> SpillConfig | None:
    """None -> off; a number -> ``SpillConfig(budget_bytes=number)``; a
    ``SpillConfig`` -> itself. A config whose budget is None/inf resolves
    to None — never spill, bit-identical to today's accumulate path."""
    if spill is None:
        return None
    cfg = (spill if isinstance(spill, SpillConfig)
           else SpillConfig(budget_bytes=float(spill)))
    return cfg if cfg.enabled else None


class _ResidentMeter:
    """Thread-safe high-water meter of the spill tier's resident wire bytes
    (host-ified pending streams + in-flight writes + read-back ranges) —
    what the acceptance bound ``peak <= budget + one chunk`` measures."""

    def __init__(self):
        self._lock = threading.Lock()
        self.cur = 0
        self.peak = 0

    def add(self, n: int):
        with self._lock:
            self.cur += int(n)
            if self.cur > self.peak:
                self.peak = self.cur

    def sub(self, n: int):
        with self._lock:
            self.cur -= int(n)


def _auto_ranges(cfg: SpillConfig, est_total_bytes: float, P: int) -> int:
    """Read-back range count: ~4 ranges per budget's worth of estimated
    spill, so one range's resident bytes sit well inside the budget.
    ``n_ranges="auto"`` consults the cost model instead (fewest ranges whose
    per-range read-back fits the flush watermark — fewer replans, each with
    fixed dispatch overhead); an int forces it; None keeps the heuristic."""
    if cfg.n_ranges == "auto":
        from repro.core.cost_model import get_cost_model
        return get_cost_model().choose_spill_ranges(
            float(est_total_bytes), float(cfg.budget_bytes), int(P),
            int(cfg.max_ranges))
    if cfg.n_ranges is not None:
        z = int(cfg.n_ranges)
    else:
        z = int(np.ceil(4.0 * float(est_total_bytes)
                        / max(float(cfg.budget_bytes), 1.0)))
    return max(1, min(z, int(P), int(cfg.max_ranges)))


def _range_record_nbytes(rec: dict) -> int:
    n = sum(int(p.nbytes) for p in rec["payloads"])
    n += (int(rec["keys"].nbytes) + int(rec["dest_eff"].nbytes)
          + int(rec["src"].nbytes))
    if rec["skey"] is not None:
        n += int(rec["skey"].nbytes)
    return n


def _streamed_reduce(store: SpillStore, meter: _ResidentMeter, jobs, P: int,
                     stats: StageStats, mesh):
    """Stream every committed partition range back through a ``Prefetcher``
    double buffer — read + host->device transfer of range z+1 hidden under
    range z's shuffle+reduce — into ``shuffle_reduce_device_streamed``.
    Exposed read waits land in ``spill_wall_s``; hidden prefetch time in
    ``overlap_hidden_s``. Each range's wire bytes leave the meter as soon
    as its reduce returns, so peak residency is O(one range)."""

    def produce(z):
        with get_tracer().span("spill-read", cat="io", range=z):
            rec = store.read_range(z)
        nb = _range_record_nbytes(rec)
        meter.add(nb)
        m = MappedSplit(
            payloads=tuple(jnp.asarray(p) for p in rec["payloads"]),
            keys=jnp.asarray(rec["keys"]),
            dest_eff=jnp.asarray(rec["dest_eff"]),
            src=jnp.asarray(rec["src"]),
            skey=(None if rec["skey"] is None
                  else jnp.asarray(rec["skey"])),
            n_rows=int(rec["n_rows"]), d=int(rec["d"]), nbytes_in=0)
        return rec["lo"], rec["hi"], m, nb

    def ranges():
        with Prefetcher(produce, depth=1, n=store.n_ranges) as pf:
            while (got := pf.get()) is not None:
                _, (lo, hi, m, nb), wait, prep = got
                stats.spill_wall_s += wait
                stats.overlap_hidden_s += max(prep - wait, 0.0)
                yield lo, hi, m
                meter.sub(nb)

    return shuffle_reduce_device_streamed(jobs, ranges(), P, stats, mesh)


class _SpillRuntime:
    """Sequential-path spill driver for device accumulate mode.

    Double-buffered in the Hadoop ``io.sort.mb`` spirit: mapped splits
    host-ify into a pending buffer; when it crosses HALF the budget it is
    handed to the store's async writer (one buffer filling while one
    drains) with at most one chunk in flight, so resident wire bytes stay
    bounded by the budget plus one chunk. A chunk bigger than half the
    budget is written synchronously instead of overlapped — tiny budgets
    degrade gracefully to spill-every-split, budget=0 included. If the run
    finishes without ever crossing the threshold, ``finish`` falls back to
    the monolithic concat+reduce verbatim (enabling spill with a roomy
    budget costs only the host-ify copies)."""

    def __init__(self, cfg: SpillConfig, P: int, K: int, stats: StageStats):
        self.cfg = cfg
        self.P = int(P)
        self.K = int(K)
        self.stats = stats
        self.budget = float(cfg.budget_bytes)
        self.meter = _ResidentMeter()
        self.pending: list = []
        self.pending_bytes = 0
        self.splits_seen = 0
        self.n_submitted = 0
        self.exposed_wait_s = 0.0
        self.store: SpillStore | None = None
        self._inflight = collections.deque()   # wire bytes per async chunk

    def _ensure_store(self) -> SpillStore:
        if self.store is None:
            root = self.cfg.dir or tempfile.mkdtemp(prefix="mr-spill-")
            self.store = SpillStore(root, self.P,
                                    write_fault=self.cfg.write_fault,
                                    on_written=self._on_written)
        return self.store

    def _on_written(self, chunk):
        # writer thread: the chunk's host buffers are on disk and dropped
        if self._inflight:
            self.meter.sub(self._inflight.popleft())

    def add(self, m: MappedSplit):
        """Host-ify one mapped split (its device buffers die with the
        caller's reference) and spill when the pending buffer fills."""
        t0 = time.perf_counter()
        h = mapped_to_host(m)
        self.stats.spill_wall_s += time.perf_counter() - t0
        nb = mapped_wire_nbytes(h)
        if self.pending and self.pending_bytes + nb > self.budget / 2:
            self._flush()                  # keep the filling buffer bounded
        self.meter.add(nb)
        self.pending.append(h)
        self.pending_bytes += nb
        self.splits_seen += 1
        if self.pending_bytes > self.budget / 2:
            self._flush()

    def _flush(self):
        if not self.pending:
            return
        store = self._ensure_store()
        if store._bounds is None:
            # first flush plans the range bounds: weight partitions by this
            # chunk's bucket counts, extrapolate total spill from the
            # splits seen so far
            w = np.zeros(self.P, np.float64)
            for h in self.pending:
                w += np.bincount(h.dest_eff, minlength=self.P + 1)[:self.P]
            est = self.pending_bytes * self.K / max(self.splits_seen, 1)
            store.set_bounds(plan_bounds(
                w, _auto_ranges(self.cfg, est, self.P)))
        t0 = time.perf_counter()
        store.wait_writes()                    # <= 1 chunk in flight
        chunk_bytes = self.pending_bytes
        self._inflight.append(chunk_bytes)
        store.submit_chunk(self.pending)
        self.n_submitted += 1
        self.stats.spilled_splits += len(self.pending)
        self.pending = []
        self.pending_bytes = 0
        if chunk_bytes > self.budget / 2:
            store.wait_writes()                # no room to overlap: go sync
        self.exposed_wait_s += time.perf_counter() - t0

    def finish(self, jobs, stats: StageStats, mesh):
        """Final reduce: streamed per-range read-back when anything
        spilled, else the monolithic concat path over the (host) pending
        streams. Same return shape as ``shuffle_reduce_device``."""
        if self.n_submitted == 0:
            stats.spill_peak_bytes = self.meter.peak
            return shuffle_reduce_device(jobs, concat_mapped(self.pending),
                                         self.P, stats, mesh)
        self._flush()                          # remainder chunk
        store = self.store
        t0 = time.perf_counter()
        store.wait_writes()
        self.exposed_wait_s += time.perf_counter() - t0
        store.sweep_staged()
        stats.spill_ranges = store.n_ranges
        out = _streamed_reduce(store, self.meter, jobs, self.P, stats, mesh)
        stats.spill_bytes += store.bytes_written
        stats.spill_chunk_bytes = store.max_chunk_bytes
        stats.spill_peak_bytes = self.meter.peak
        stats.spill_wall_s += self.exposed_wait_s
        stats.overlap_hidden_s += max(
            store.write_wall_s - self.exposed_wait_s, 0.0)
        return out

    def close(self):
        if self.store is not None:
            self.store.close()


# ---------------------------------------------------------------------------
# LanePool: concurrent split lanes + executed speculative re-execution
# ---------------------------------------------------------------------------

class LaneCancelled(Exception):
    """Internal control flow: a losing attempt noticed its cancel event
    between stages and unwound; its partial buffers are dropped."""


class JobDeadlineExceeded(TimeoutError):
    """The per-job ``deadline_s`` elapsed before every split committed."""


#: exceptions a lane treats as transient — re-dispatched with bounded
#: backoff up to ``max_retries`` (Hadoop's per-task retry budget)
RETRYABLE = (TransientSplitError, OSError)


@dataclasses.dataclass
class _LaneTask:
    """One dispatchable unit: run ``fn(cancel_event)`` for split ``key``."""
    key: int
    fn: object
    attempt: int = 0
    clone: bool = False


@dataclasses.dataclass
class _Lane:
    """One worker lane: a thread, optionally pinned to a device."""
    id: int
    thread: threading.Thread | None = None
    alive: bool = True
    declared_dead: bool = False     # liveness machine gave up on it
    last_beat: float = 0.0
    n_tasks: int = 0
    busy_s: float = 0.0
    dead_reason: str = ""


class LanePool:
    """Concurrent split lanes with first-finisher-wins speculative cloning —
    the scheduler that turns ``ft.SpeculativePolicy`` from advisory into
    executed (Hadoop's speculative task re-execution, for real).

    ``n_lanes`` worker threads pull ``_LaneTask``s off one priority queue
    (clones outrank fresh work — a speculation that queues behind the
    backlog can never win). Per key, the FIRST attempt to finish commits —
    its payload lands in ``results`` and the pool's ``on_commit`` hook runs
    under the lock — and every other in-flight attempt for that key is
    cancelled via its ``threading.Event`` (task fns poll it between stages;
    chaos-injected stalls poll it mid-sleep), so the loser unwinds and its
    buffers die with the frame. Commutative merge contracts make the result
    bit-identical whichever attempt wins.

    Failure ladder, per task:

    - ``RETRYABLE`` (transient fetch errors): re-dispatched with bounded
      exponential backoff, up to ``max_retries``; the budget's last failure
      becomes the run's fatal error.
    - ``LaneDeath``: the lane marks itself dead, requeues the task onto the
      surviving lanes at clone priority, and its thread exits — the pool
      *shrinks* instead of hanging.
    - anything else: fatal; ``drain`` raises it.

    ``drain`` is the control loop (runs on the caller's thread): it feeds
    lane heartbeats into an ``ft.Coordinator`` — the SAME heartbeat ->
    degraded -> remesh state machine the training launcher uses — and
    executes its verdicts (remesh = declare stuck lanes dead, cancel and
    requeue their work; abort = every lane is gone), enforces the per-job
    ``deadline_s``, and drives the speculation policy: per tick it reports
    ``running(split, elapsed)`` for in-flight splits and executes
    ``propose()``'s verdict by cloning the slow split onto a free lane.

    Context manager: exit joins every lane thread and (on a clean exit)
    raises if any survived the join — the no-leaked-threads guarantee that
    pairs with ``Prefetcher.stop``'s stuck-fetch error.
    """

    def __init__(self, n_lanes: int, *, policy: SpeculativePolicy | None = None,
                 chaos=None, max_retries: int = 2, backoff_s: float = 0.02,
                 deadline_s: float | None = None, devices=None,
                 liveness_cfg: CoordinatorConfig | None = None,
                 stuck_after_s: float | None = None, on_commit=None,
                 join_timeout_s: float = 30.0, name: str = "lane"):
        assert n_lanes >= 1
        self.n_lanes = int(n_lanes)
        self.policy = policy
        self.chaos = chaos
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.deadline_s = deadline_s
        self.devices = list(devices) if devices else None
        self.stuck_after_s = stuck_after_s
        self.on_commit = on_commit
        self.join_timeout_s = float(join_timeout_s)
        self._clock = time.perf_counter
        self._q: queue.PriorityQueue = queue.PriorityQueue()
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._fatal: BaseException | None = None
        self._inflight: dict[int, dict] = {}        # id(task) -> record
        self._by_key: dict[int, list] = {}
        self.submitted: set[int] = set()
        self.results: dict[int, object] = {}
        self.meta: dict[int, dict] = {}             # key -> winning attempt info
        self.retries = 0
        self.speculated = 0
        self.clone_wins = 0
        self.cancelled = 0
        self.dup_drops = 0
        self.lane_deaths = 0
        self.remeshes: list[dict] = []
        self.liveness = Coordinator(
            list(range(self.n_lanes)),
            liveness_cfg or CoordinatorConfig(heartbeat_timeout=0.05,
                                              misses_to_degrade=2,
                                              misses_to_dead=4, min_hosts=1))
        now = self._clock()
        self.lanes = [_Lane(i, last_beat=now) for i in range(self.n_lanes)]
        for lane in self.lanes:
            lane.thread = threading.Thread(
                target=self._worker, args=(lane,),
                name=f"{name}-{lane.id}", daemon=True)
            lane.thread.start()

    # -- submission / results ------------------------------------------------

    @property
    def width(self) -> int:
        """Lanes still alive (the pool shrinks on lane death)."""
        return sum(lane.alive for lane in self.lanes)

    def submit(self, key: int, fn, *, clone: bool = False):
        with self._lock:
            self._submit_locked(_LaneTask(int(key), fn, clone=clone))

    def _submit_locked(self, task: _LaneTask):
        self.submitted.add(task.key)
        # clones and re-dispatches jump the queue: priority 0 beats 1
        self._q.put((0 if (task.clone or task.attempt) else 1,
                     next(self._seq), task))

    # -- the worker lanes ----------------------------------------------------

    def _lane_ctx(self, lane: _Lane):
        """Per-device lanes: pin this lane's computations (and implicit
        ``device_put`` targets) to its own device when a device list was
        given — concurrent splits then run on distinct devices, the
        mesh-as-lanes execution model."""
        if self.devices:
            return jax.default_device(self.devices[lane.id % len(self.devices)])
        return contextlib.nullcontext()

    def _worker(self, lane: _Lane):
        with self._lane_ctx(lane):
            while not self._stop.is_set():
                try:
                    _, _, task = self._q.get(timeout=0.01)
                except queue.Empty:
                    lane.last_beat = self._clock()
                    continue
                with self._lock:
                    if task.key in self.results or self._fatal is not None:
                        continue            # stale: this split already won
                    cancel = threading.Event()
                    rec = {"task": task, "lane": lane.id,
                           "t0": self._clock(), "cancel": cancel}
                    self._inflight[id(task)] = rec
                    self._by_key.setdefault(task.key, []).append(rec)
                lane.n_tasks += 1
                t0 = self._clock()
                requeue = None
                dead = False
                tr = get_tracer()
                try:
                    # the lane-exec span closes in its finally even when the
                    # task dies mid-stage (chaos kill, cancel, transient
                    # fault) — the exception then continues into the ladder
                    # below with every opened span closed
                    with tr.ids(lane=lane.id, split=task.key), \
                         tr.span("lane-exec", cat="lane", lane=lane.id,
                                 split=task.key, attempt=task.attempt,
                                 clone=task.clone):
                        if self.chaos is not None:
                            self.chaos.on_task_start(lane.id, task.key,
                                                     task.attempt, cancel)
                        out = task.fn(cancel)
                except (LaneCancelled, CancelledFetch):
                    with self._lock:
                        self.cancelled += 1
                except LaneDeath as e:
                    with self._lock:
                        lane.alive = False
                        lane.dead_reason = str(e)
                        self.lane_deaths += 1
                        # the dying lane's split must not be lost: requeue a
                        # fresh copy onto the survivors at clone priority
                        self._submit_locked(dataclasses.replace(task))
                    dead = True
                except RETRYABLE as e:
                    if task.attempt >= self.max_retries:
                        with self._lock:
                            if self._fatal is None:
                                self._fatal = e
                    else:
                        requeue = dataclasses.replace(task,
                                                      attempt=task.attempt + 1)
                except BaseException as e:
                    with self._lock:
                        if self._fatal is None:
                            self._fatal = e
                else:
                    self._commit(task, out, self._clock() - t0, lane)
                finally:
                    with self._lock:
                        self._inflight.pop(id(task), None)
                        self._by_key.get(task.key, [])[:] = [
                            r for r in self._by_key.get(task.key, ())
                            if r["task"] is not task]
                    lane.busy_s += self._clock() - t0
                    lane.last_beat = self._clock()
                if dead:
                    return
                if requeue is not None:
                    # bounded exponential backoff, interruptible on shutdown
                    with tr.span("retry", cat="lane", lane=lane.id,
                                 split=task.key, attempt=requeue.attempt):
                        self._stop.wait(self.backoff_s * (2 ** task.attempt))
                    with self._lock:
                        self.retries += 1
                        self._submit_locked(requeue)

    def _commit(self, task: _LaneTask, out, wall_s: float, lane: _Lane):
        with self._lock:
            if task.key in self.results:
                self.dup_drops += 1     # lost the race; buffers die here
                return
            meta = {"lane": lane.id, "attempt": task.attempt,
                    "clone": task.clone, "wall_s": wall_s}
            self.results[task.key] = out
            self.meta[task.key] = meta
            if task.clone:
                self.clone_wins += 1
                get_tracer().instant("clone-win", cat="lane",
                                     split=task.key, lane=lane.id)
            for rec in self._by_key.get(task.key, ()):
                if rec["task"] is not task:
                    rec["cancel"].set()         # losers: unwind between stages
            if self.policy is not None:
                self.policy.finished(task.key, wall_s)
            if self.on_commit is not None:
                self.on_commit(task.key, out, meta)

    # -- the control loop: liveness, deadline, speculation -------------------

    def drain(self, keys=None, *, make_task_fn=None, tick_s: float = 0.002):
        """Block until every key has committed (default: everything
        submitted). Runs the lane-liveness state machine, the per-job
        deadline, and the speculation policy; raises the first fatal error,
        ``JobDeadlineExceeded``, or abort (all lanes dead)."""
        t_start = self._clock()
        while True:
            with self._lock:
                want = set(self.submitted if keys is None else keys)
                fatal = self._fatal
                done = want <= self.results.keys()
            if fatal is not None:
                raise fatal
            if done:
                return
            now = self._clock()
            if (self.deadline_s is not None
                    and now - t_start > self.deadline_s):
                missing = sorted(want - set(self.results))
                raise JobDeadlineExceeded(
                    f"job deadline {self.deadline_s}s exceeded with splits "
                    f"{missing} uncommitted ({self.width}/{self.n_lanes} "
                    f"lanes alive)")
            self._liveness_tick(now)
            self._speculate(now, make_task_fn)
            time.sleep(tick_s)

    def _liveness_tick(self, now: float):
        coord = self.liveness
        with self._lock:
            for lane in self.lanes:
                beating = lane.alive and (
                    self.stuck_after_s is None
                    or now - lane.last_beat <= self.stuck_after_s)
                if beating:
                    coord.heartbeat(lane.id, now)
            act = coord.tick(now)
            if act["action"] == "remesh":
                for lid in act["dead"]:
                    lane = self.lanes[lid]
                    lane.declared_dead = True
                    if lane.alive:
                        # stuck, not self-reported: give up on it — cancel
                        # its in-flight work and requeue fresh copies
                        lane.alive = False
                        lane.dead_reason = (lane.dead_reason
                                            or "no heartbeat (stuck)")
                        for rec in list(self._inflight.values()):
                            if rec["lane"] == lid:
                                rec["cancel"].set()
                                self._submit_locked(
                                    dataclasses.replace(rec["task"]))
                self.remeshes.append(act)
                coord.remesh_done()
            elif act["action"] == "abort":
                if self._fatal is None:
                    self._fatal = RuntimeError(
                        "every lane is dead: "
                        + "; ".join(f"lane {ln.id}: {ln.dead_reason}"
                                    for ln in self.lanes if not ln.alive))

    def _speculate(self, now: float, make_task_fn):
        if self.policy is None:
            return
        with self._lock:
            earliest: dict[int, float] = {}
            for rec in self._inflight.values():
                k = rec["task"].key
                earliest[k] = min(earliest.get(k, rec["t0"]), rec["t0"])
            for k, t0 in earliest.items():
                if k not in self.results:
                    self.policy.running(k, now - t0)
            verdict = self.policy.propose()
            if verdict["action"] == "speculate" and make_task_fn is not None:
                k = verdict["split"]
                self.speculated += 1
                get_tracer().instant("clone-race", cat="lane", split=k)
                self._submit_locked(_LaneTask(k, make_task_fn(k), clone=True))

    # -- shutdown ------------------------------------------------------------

    def shutdown(self, *, check: bool = True):
        self._stop.set()
        with self._lock:
            for rec in self._inflight.values():
                rec["cancel"].set()
        leaked = []
        for lane in self.lanes:
            if lane.thread is not None:
                lane.thread.join(timeout=self.join_timeout_s)
                if lane.thread.is_alive():
                    leaked.append(lane.id)
        if leaked and check:
            raise RuntimeError(
                f"LanePool shutdown leaked lane thread(s) {leaked}: still "
                f"running {self.join_timeout_s}s after stop — a task is "
                f"ignoring its cancel event")

    def __enter__(self) -> "LanePool":
        return self

    def __exit__(self, exc_type, exc, tb):
        # on the error path, still stop + join but don't let a leak report
        # mask the original failure
        self.shutdown(check=exc_type is None)


# ---------------------------------------------------------------------------
# The streaming executor
# ---------------------------------------------------------------------------

def _resolve_policy(speculate) -> SpeculativePolicy | None:
    """None/False -> off; True -> default policy; a ``SpeculativeConfig``
    or ``SpeculativePolicy`` -> that policy."""
    if not speculate:
        return None
    if isinstance(speculate, SpeculativePolicy):
        return speculate
    if speculate is True:
        return SpeculativePolicy()
    return SpeculativePolicy(speculate)      # a SpeculativeConfig


def run_jobs_streaming(jobs, source: SplitSource, *, mesh=None,
                       engine: str = "auto", combiner="auto",
                       prefetch: int = 2, straggler_monitor=None,
                       n_lanes: int = 1, speculate=None, chaos=None,
                       max_retries: int = 0, retry_backoff_s: float = 0.05,
                       deadline_s: float | None = None,
                       spill=None) -> list[JobResult]:
    """Stream every split of ``source`` through map -> combine -> shuffle ->
    reduce and return one ``JobResult`` per job (all sharing one
    ``StageStats`` with per-split records).

    - ``combiner="auto"`` derives the map-side combine from the reducers
      (see ``_resolve_combiner``); ``None`` disables it (splits accumulate
      as wire-dtype streams, one global reduce at the end); a ``Combiner``
      instance forces it.
    - ``prefetch`` is the double-buffer depth: >0 fetches + device-transfers
      split k+1 on a background thread while split k computes
      (``overlap_hidden_s`` records what that hid); 0 runs synchronously
      (what ``run_jobs`` uses for its one-split delegate).
    - ``straggler_monitor`` (``ft.StragglerMonitor``) receives
      ``record(split_index, split_wall_s)`` per split, so slow splits can
      drive Hadoop-style speculative re-execution policy
      (``ft.SpeculativePolicy``).
    - ``mesh`` composes: per-split (or final) reduces run psum-sharded over
      the ``data`` axis; cross-split combine sees the replicated partial.

    Lane execution (any of the following engages the ``LanePool`` path;
    the default is the sequential prefetched pipeline above):

    - ``n_lanes > 1``: splits dispatch concurrently over worker lanes —
      pinned one-per-device when several devices exist and no ``mesh`` is
      given (the mesh-as-lanes model: different splits on different
      devices, Hadoop's actual parallelism), else concurrent dispatch
      streams on one device.
    - ``speculate``: True / ``SpeculativeConfig`` / ``SpeculativePolicy`` —
      the policy's verdicts are EXECUTED: a slow split is cloned onto a
      free lane, first finisher wins, the loser is cancelled between
      stages. Bit-identical results either way (commutative merges).
    - ``chaos`` (``ft.LaneChaos``): injected lane deaths/delays; a dead
      lane's work requeues onto the survivors and the pool shrinks.
    - ``max_retries`` / ``retry_backoff_s``: per-split transient-fault
      retry budget with bounded exponential backoff.
    - ``deadline_s``: per-job deadline — ``JobDeadlineExceeded`` instead of
      a hang when splits cannot finish.

    ``spill`` (a byte budget or a ``SpillConfig``) engages the external
    shuffle tier for device-engine accumulate mode (no valid combiner):
    when the accumulated wire streams exceed the budget they spill to
    partition-range-bucketed segment files and the final reduce streams
    each range back through a prefetch double buffer — peak resident wire
    bytes O(spill chunk) instead of O(catalog/codec ratio), bit-identical
    for any budget (0 = spill everything, None/inf = never spill ≡ off).
    When a combiner is active nothing accumulates, so ``spill`` is a
    no-op; the host engine rejects it. With lanes, every split's stream
    spills at map time (segments commit with the split, so retried/cloned
    splits stay lane-safe). Spill files live under ``SpillConfig.dir`` (a
    fresh temp dir by default) and are reclaimed on exit, success or
    failure.

    The partition space must be split-independent (``n_partitions`` is read
    from the first split) — true for the stock zone/hash partitioners.
    """
    if not jobs:
        return []
    # codec="auto" materializes here, BEFORE signature validation — every
    # downstream get_codec/shuffle_signature sees a concrete codec. The
    # cost model only picks among exact codecs, so results cannot change.
    jobs = [resolve_auto_job(j) for j in jobs]
    validate_batch(jobs)
    if engine == "auto":
        engine = "device"
    if engine not in ("device", "host"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "expected 'auto', 'device', or 'host'")
    j0 = jobs[0]
    codec = get_codec(j0.codec)
    part = j0.partitioner
    comb = _resolve_combiner(combiner, jobs, codec)
    K = int(source.n_splits())
    device = engine == "device"
    spill_cfg = _resolve_spill(spill)
    if spill_cfg is not None and not device:
        raise ValueError("spill= requires the device engine: the spill "
                         "tier stores wire-dtype encoded streams")
    if comb is not None:
        spill_cfg = None     # combine mode never accumulates: nothing to spill
    stats = StageStats(job="+".join(j.name for j in jobs), engine=engine,
                       codec=codec.name, n_splits=K,
                       combiner=comb.name if comb else "")
    policy = _resolve_policy(speculate)
    tr = get_tracer()
    meter = get_meter()
    mtok = meter.begin()
    if (n_lanes > 1 or policy is not None or chaos is not None
            or max_retries > 0 or deadline_s is not None):
        t_job0 = time.perf_counter()
        out = _run_jobs_lanes(
            jobs, source, mesh=mesh, device=device, codec=codec, part=part,
            comb=comb, K=K, stats=stats, straggler_monitor=straggler_monitor,
            n_lanes=max(1, int(n_lanes)), policy=policy, chaos=chaos,
            max_retries=max_retries, retry_backoff_s=retry_backoff_s,
            deadline_s=deadline_s, spill_cfg=spill_cfg)
        if tr.enabled:
            tr.record("job", t_job0, time.perf_counter(), cat="job",
                      job=stats.job, mode="lanes")
        meter.attribute(mtok, stats)
        return out
    t_job0 = time.perf_counter()

    def fetch(k):
        # -> (items, raw_rows, raw_bytes): the RAW split size is carried
        # alongside so n_items/map_bytes report what was actually fetched,
        # not the combiner's pre-aggregated rewrite
        s = source.split(k)
        raw_rows, raw_bytes = len(s), int(np.asarray(s).nbytes)
        if comb is not None:
            s = comb.precombine(s)
        return s, raw_rows, raw_bytes

    def fetch_to_device(k):
        # runs on the prefetch thread: host I/O, precombine, AND the
        # host->device transfer all overlap the main thread's compute
        with tr.span("fetch", cat="io", split=k):
            s, raw_rows, raw_bytes = fetch(k)
            return (jax.device_put(np.ascontiguousarray(
                np.asarray(s, np.float32))), raw_rows, raw_bytes)

    def synchronous():
        for k in range(K):
            t0 = time.perf_counter()
            item = fetch(k)
            dt = time.perf_counter() - t0
            yield k, item, dt, dt

    acc = None
    mapped = []
    host_items = []
    recs = []
    agg = _Agg()
    raw_items_total = 0
    raw_bytes_total = 0
    P = None
    spill_rt = None

    def consume(k, item, wait_s, prep_s):
        nonlocal acc, P, raw_items_total, raw_bytes_total, spill_rt
        items_k, raw_rows, raw_bytes = item
        raw_items_total += raw_rows
        raw_bytes_total += raw_bytes
        stats.fetch_wall_s += wait_s
        stats.overlap_hidden_s += max(prep_s - wait_s, 0.0)
        if tr.enabled and wait_s > 0:
            # the wait just ended: record the exposed fetch stall span
            # retroactively (the hidden part already traced as "fetch" on
            # the prefetch thread)
            t_now = tr.now()
            tr.record("fetch-wait", t_now - wait_s, t_now, cat="io", split=k)
        if P is None:
            P = int(part.n_partitions(items_k))
        rec = {"split": k, "n_items": raw_rows, "fetch_wait_s": wait_s,
               "fetch_prep_s": prep_s}
        m0, s0, r0 = stats.map_wall_s, stats.shuffle_wall_s, stats.reduce_wall_s
        if device:
            t0 = time.perf_counter()
            m = map_split_device(part, codec, items_k, P)
            stats.map_wall_s += time.perf_counter() - t0
            if comb is None:
                if spill_cfg is not None:
                    if spill_rt is None:
                        spill_rt = _SpillRuntime(spill_cfg, P, K, stats)
                    spill_rt.add(m)      # host-ify + maybe flush to disk
                else:
                    mapped.append(m)
            else:
                totals, sd, sp, sr = shuffle_reduce_device(jobs, m, P, stats,
                                                           mesh)
                agg.add(sd, sp, sr)
                with tr.span("combine", cat="stage", split=k):
                    t0 = time.perf_counter()
                    acc = comb.combine(acc, totals)
                    stats.combine_wall_s += time.perf_counter() - t0
        else:
            items_h = np.asarray(items_k)
            if comb is None:
                host_items.append(items_h)
            else:
                totals, sd, sp, sr = host_shuffle_reduce(jobs, items_h,
                                                         stats, mesh)
                agg.add(sd, sp, sr)
                with tr.span("combine", cat="stage", split=k):
                    t0 = time.perf_counter()
                    acc = comb.combine(acc, totals)
                    stats.combine_wall_s += time.perf_counter() - t0
        rec["map_s"] = stats.map_wall_s - m0
        rec["shuffle_s"] = stats.shuffle_wall_s - s0
        rec["reduce_s"] = stats.reduce_wall_s - r0
        # the split's own end-to-end cost: its fetch/transfer work (prep, as
        # measured in the producer whether or not it was hidden) plus its
        # processing walls. In accumulate mode processing is deferred to the
        # one global reduce, so per-split cost is I/O-dominated — exactly
        # the signal Hadoop's speculative execution watches (a split whose
        # read stalls shows up here even when other splits hid theirs).
        rec["wall_s"] = (prep_s + rec["map_s"] + rec["shuffle_s"]
                         + rec["reduce_s"])
        recs.append(rec)
        if straggler_monitor is not None:
            straggler_monitor.record(k, rec["wall_s"])

    try:
        if K > 1 and prefetch > 0:
            produce = fetch_to_device if device else fetch
            with Prefetcher(produce, depth=prefetch, n=K) as pf:
                while (got := pf.get()) is not None:
                    with tr.ids(split=got[0]):
                        consume(*got)
        else:
            for got in synchronous():
                with tr.ids(split=got[0]):
                    consume(*got)
        assert len(recs) == K, (len(recs), K)

        if comb is None:
            # no valid map-side combine: the accumulated wire-format streams
            # cross ONE global shuffle+reduce (Hadoop's reduce-after-last-map)
            # — streamed per partition range from disk when they spilled
            if device:
                if spill_rt is not None:
                    totals, sd, sp, sr = spill_rt.finish(jobs, stats, mesh)
                else:
                    totals, sd, sp, sr = shuffle_reduce_device(
                        jobs, concat_mapped(mapped), P, stats, mesh)
            else:
                items_all = (host_items[0] if len(host_items) == 1
                             else np.concatenate(host_items, axis=0))
                totals, sd, sp, sr = host_shuffle_reduce(jobs, items_all,
                                                         stats, mesh)
            agg.add(sd, sp, sr)
            summary = sd
        else:
            t0 = time.perf_counter()
            totals = jax.block_until_ready(acc)
            stats.combine_wall_s += time.perf_counter() - t0
            summary = agg.summary()
    finally:
        if spill_rt is not None:
            spill_rt.close()         # reclaim segments, success or failure
    agg.finish(stats)
    # n_items/map_bytes always mean the RAW catalog (what the maps read) —
    # the per-split stages counted post-precombine rows when a combiner ran
    stats.n_items = raw_items_total
    stats.map_bytes = raw_bytes_total
    stats.splits = tuple(recs)
    if tr.enabled:
        tr.record("job", t_job0, time.perf_counter(), cat="job",
                  job=stats.job, mode="stream")
    meter.attribute(mtok, stats)
    return [JobResult(j.reducer.finalize(t, summary), stats)
            for j, t in zip(jobs, totals)]


def _fence_mapped(m):
    """Block until one ``MappedSplit``'s arrays are materialized, so a lane's
    reported wall covers real device work, not dispatch."""
    jax.block_until_ready([m.payloads, m.keys, m.dest_eff, m.src]
                          + ([m.skey] if m.skey is not None else []))
    return m


def _run_jobs_lanes(jobs, source, *, mesh, device, codec, part, comb, K,
                    stats, straggler_monitor, n_lanes, policy, chaos,
                    max_retries, retry_backoff_s, deadline_s,
                    spill_cfg=None):
    """The ``LanePool`` execution path of ``run_jobs_streaming``: splits run
    concurrently, each lane's stages fill a PRIVATE ``StageStats`` that
    merges into the shared one at commit (under the pool lock, so the
    stage-wall accumulation the sequential path does in-place stays
    race-free), and only the FIRST committed attempt per split contributes —
    a cancelled speculation loser's partial work is dropped with its frame.
    Commit order is nondeterministic; every cross-split merge is commutative
    (integer-sum accumulators / multiset bucket contents), which is exactly
    the contract that makes the results bit-identical to the sequential and
    monolithic paths."""
    t_run0 = time.perf_counter()
    devices = None
    if device and mesh is None:
        devs = jax.devices()
        if len(devs) > 1:
            devices = devs        # per-device lanes (lane i -> device i % D)

    agg = _Agg()
    mapped: dict[int, object] = {}
    host_items: dict[int, np.ndarray] = {}
    recs: list[dict] = []
    state = {"acc": None, "P": None, "raw_items": 0, "raw_bytes": 0}

    # Lane-mode spill: every split's stream is staged to disk by its own
    # lane (no cross-lane accumulation buffer to bound — lanes run
    # concurrently, so the budget degenerates to spill-per-split) and the
    # winning attempt's segments are finalize-renamed in on_commit, under
    # the pool lock. Losing clones leave only staged litter, swept before
    # read-back. The first lane to stage plans the range bounds.
    spill_state = None
    if spill_cfg is not None and device and comb is None:
        spill_state = {"cfg": spill_cfg, "store": None,
                       "meter": _ResidentMeter(),
                       "lock": threading.Lock(),
                       "ready": threading.Event()}

    def spill_store_for(h, P_k):
        st = spill_state
        if not st["ready"].is_set():
            with st["lock"]:
                if not st["ready"].is_set():
                    root = (st["cfg"].dir
                            or tempfile.mkdtemp(prefix="mr-spill-"))
                    store = SpillStore(root, P_k,
                                       write_fault=st["cfg"].write_fault)
                    w = np.bincount(h.dest_eff, minlength=P_k + 1)[:P_k]
                    est = mapped_wire_nbytes(h) * K
                    store.set_bounds(plan_bounds(
                        w, _auto_ranges(st["cfg"], est, P_k)))
                    st["store"] = store
                    st["ready"].set()
        st["ready"].wait()
        return st["store"]

    def fetch(k, cancel):
        if hasattr(source, "split_cancellable"):
            s = source.split_cancellable(k, cancel)
        else:
            s = source.split(k)
        raw_rows, raw_bytes = len(s), int(np.asarray(s).nbytes)
        if comb is not None:
            s = comb.precombine(s)
        return s, raw_rows, raw_bytes

    def make_task(k):
        def fn(cancel):
            tr = get_tracer()
            local = StageStats()
            t0 = time.perf_counter()
            s, raw_rows, raw_bytes = fetch(k, cancel)
            t1 = time.perf_counter()
            local.fetch_wall_s = t1 - t0
            if tr.enabled:
                # lane fetches are synchronous, so the whole fetch is an
                # exposed wait from the lane's point of view
                tr.record("fetch-wait", t0, t1, cat="io", split=k)
            if cancel.is_set():
                raise LaneCancelled(k)
            P_k = int(part.n_partitions(s))
            if device:
                items_k = jax.device_put(np.ascontiguousarray(
                    np.asarray(s, np.float32)))
                t0 = time.perf_counter()
                m = map_split_device(part, codec, items_k, P_k)
                local.map_wall_s += time.perf_counter() - t0
                if cancel.is_set():
                    raise LaneCancelled(k)
                if comb is None:
                    if spill_state is not None:
                        t0 = time.perf_counter()
                        h = mapped_to_host(_fence_mapped(m))
                        del m, items_k       # device buffers reclaimable now
                        nb = mapped_wire_nbytes(h)
                        store = spill_store_for(h, P_k)
                        spill_state["meter"].add(nb)
                        try:
                            if cancel.is_set():
                                raise LaneCancelled(k)
                            chunk = store.stage_chunk([h], store.next_tag())
                        finally:
                            spill_state["meter"].sub(nb)
                        local.spill_wall_s += time.perf_counter() - t0
                        local.spilled_splits = 1
                        payload = ("spilled", chunk, nb)
                    else:
                        payload = ("mapped", _fence_mapped(m))
                else:
                    totals, sd, sp, sr = shuffle_reduce_device(
                        jobs, m, P_k, local, mesh)
                    payload = ("acc", jax.block_until_ready(totals),
                               sd, sp, sr)
            else:
                items_h = np.asarray(s)
                if comb is None:
                    payload = ("items", items_h)
                else:
                    totals, sd, sp, sr = host_shuffle_reduce(
                        jobs, items_h, local, mesh)
                    payload = ("acc", totals, sd, sp, sr)
            if cancel.is_set():
                raise LaneCancelled(k)
            return {"payload": payload, "P": P_k, "raw_rows": raw_rows,
                    "raw_bytes": raw_bytes, "local": local}
        return fn

    def on_commit(k, out, meta):
        # runs under the pool lock: the one winning attempt per split merges
        # its private stats + partials into the shared state, serialized
        local = out["local"]
        stats.merge_from(local)
        state["raw_items"] += out["raw_rows"]
        state["raw_bytes"] += out["raw_bytes"]
        if state["P"] is None:
            state["P"] = out["P"]
        kind, *rest = out["payload"]
        if kind == "acc":
            totals, sd, sp, sr = rest
            agg.add(sd, sp, sr)
            with get_tracer().span("combine", cat="stage", split=k):
                t0 = time.perf_counter()
                state["acc"] = comb.combine(state["acc"], totals)
                stats.combine_wall_s += time.perf_counter() - t0
        elif kind == "spilled":
            # lane-safe commit: the winning attempt's staged segments
            # finalize-rename here, serialized under the pool lock; a
            # losing clone's chunk never reaches this hook
            spill_state["store"].commit_chunk(rest[0])
        elif kind == "mapped":
            mapped[k] = rest[0]
        else:
            host_items[k] = rest[0]
        recs.append({"split": k, "n_items": out["raw_rows"],
                     "fetch_wait_s": local.fetch_wall_s,
                     "fetch_prep_s": local.fetch_wall_s,
                     "map_s": local.map_wall_s,
                     "shuffle_s": local.shuffle_wall_s,
                     "reduce_s": local.reduce_wall_s,
                     "wall_s": meta["wall_s"], "lane": meta["lane"],
                     "attempt": meta["attempt"], "clone": meta["clone"]})
        if straggler_monitor is not None and straggler_monitor is not policy:
            straggler_monitor.record(k, meta["wall_s"])

    try:
        with LanePool(n_lanes, policy=policy, chaos=chaos,
                      max_retries=max_retries, backoff_s=retry_backoff_s,
                      deadline_s=deadline_s, devices=devices,
                      on_commit=on_commit) as pool:
            for k in range(K):
                pool.submit(k, make_task(k))
            pool.drain(range(K), make_task_fn=make_task)
            stats.n_lanes = n_lanes
            stats.speculated = pool.speculated
            stats.clone_wins = pool.clone_wins
            stats.retries = pool.retries
            stats.lane_walls = tuple(round(ln.busy_s, 6)
                                     for ln in pool.lanes)
        assert len(recs) == K, (len(recs), K)

        P = state["P"]
        if comb is None:
            # one global shuffle+reduce over the accumulated per-split
            # streams — streamed back per partition range when they
            # spilled, else concatenated in split order (deterministic
            # regardless of commit order — and bit-identical to any order
            # by the multiset contract)
            if device:
                if spill_state is not None:
                    store = spill_state["store"]
                    store.sweep_staged()     # cancelled clones' litter
                    stats.spill_ranges = store.n_ranges
                    totals, sd, sp, sr = _streamed_reduce(
                        store, spill_state["meter"], jobs, P, stats, mesh)
                    stats.spill_bytes += store.bytes_written
                    stats.spill_chunk_bytes = store.max_chunk_bytes
                    stats.spill_peak_bytes = spill_state["meter"].peak
                else:
                    totals, sd, sp, sr = shuffle_reduce_device(
                        jobs, concat_mapped([mapped[k] for k in range(K)]),
                        P, stats, mesh)
            else:
                hs = [host_items[k] for k in range(K)]
                items_all = (hs[0] if len(hs) == 1
                             else np.concatenate(hs, axis=0))
                totals, sd, sp, sr = host_shuffle_reduce(jobs, items_all,
                                                         stats, mesh)
            agg.add(sd, sp, sr)
            summary = sd
        else:
            t0 = time.perf_counter()
            totals = jax.block_until_ready(state["acc"])
            stats.combine_wall_s += time.perf_counter() - t0
            summary = agg.summary()
    finally:
        if spill_state is not None and spill_state["store"] is not None:
            spill_state["store"].close()
    agg.finish(stats)
    stats.n_items = state["raw_items"]
    stats.map_bytes = state["raw_bytes"]
    stats.splits = tuple(sorted(recs, key=lambda r: r["split"]))
    stats.elapsed_s = time.perf_counter() - t_run0
    return [JobResult(j.reducer.finalize(t, summary), stats)
            for j, t in zip(jobs, totals)]


def run_job_streaming(job, source: SplitSource, *, mesh=None,
                      engine: str = "auto", combiner="auto",
                      prefetch: int = 2, straggler_monitor=None,
                      n_lanes: int = 1, speculate=None, chaos=None,
                      max_retries: int = 0, retry_backoff_s: float = 0.05,
                      deadline_s: float | None = None,
                      spill=None) -> JobResult:
    """Stream one job over a ``SplitSource``. -> JobResult(output, stats)."""
    return run_jobs_streaming([job], source, mesh=mesh, engine=engine,
                              combiner=combiner, prefetch=prefetch,
                              straggler_monitor=straggler_monitor,
                              n_lanes=n_lanes, speculate=speculate,
                              chaos=chaos, max_retries=max_retries,
                              retry_backoff_s=retry_backoff_s,
                              deadline_s=deadline_s, spill=spill)[0]
