"""Neighbor Searching (the paper's data-intensive app): all pairs within theta.

Zones algorithm [Gray/Nieto-Santisteban/Szalay, MSR-TR-2006-52]: zone buckets are
self-contained (borders replicated), so each zone's pairs are found independently by
the blockwise pair kernel. Every within-radius unordered pair (p, q) is seen exactly
twice across zones (once from each endpoint's own zone), plus each owned point sees
itself once; the final count corrects for both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import sky
from repro.kernels.zones_pairs.ops import pair_count, pair_hist
from repro.mapreduce.api import ZonedData, bucket_by_zone, sharded_zone_reduce


def neighbor_search_count(xyz: np.ndarray, radius_rad: float, *, mesh=None,
                          compress_coords: bool = False,
                          use_pallas: bool | None = None,
                          tile: int = 256, zone_height: float = 0.0) -> int:
    """Total number of unordered neighbor pairs within radius."""
    pad_z = (mesh.shape["data"] if mesh is not None and
             "data" in mesh.axis_names else 1)
    zd = bucket_by_zone(xyz, radius_rad, tile=tile, zone_height=zone_height,
                        compress_coords=compress_coords, pad_zones_to=pad_z)
    cmin = float(np.cos(radius_rad))

    def per_zone(owned_z, bucket_z):
        return pair_count(owned_z, bucket_z, cmin, use_pallas=use_pallas)

    total = int(sharded_zone_reduce(per_zone, zd, mesh))
    n_self = int(zd.n_owned.sum())
    return (total - n_self) // 2


def neighbor_pairs_dense(xyz: np.ndarray, radius_rad: float):
    """Small-N exact pair list (test oracle / example output)."""
    dots = xyz @ xyz.T
    np.fill_diagonal(dots, -2)
    i, j = np.where(dots >= np.cos(radius_rad))
    keep = i < j
    return np.stack([i[keep], j[keep]], axis=1)
