"""Neighbor Searching (the paper's data-intensive app) as a MapReduce job.

Zones algorithm [Gray/Nieto-Santisteban/Szalay, MSR-TR-2006-52]: declination
bands with border replication make each zone bucket self-contained, so a
blockwise pair kernel reduces every zone independently. Every within-radius
unordered pair (p, q) is seen exactly twice across zones (once from each
endpoint's own zone), plus each owned point sees itself once; ``finalize``
corrects for both.

This module is now a thin definition on the composable Job API
(``mapreduce/job.py``): ``ZonePartitioner`` is the map-stage plugin (zone
assignment + border-replication policy), ``PairCountReducer`` the
reduce-stage plugin, and ``neighbor_search_job`` wires them together with any
registered shuffle codec. ``neighbor_search_count`` keeps the original
signature as a deprecated wrapper.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np

from repro.data import sky
from repro.kernels.zones_pairs.ops import pair_count, pair_count_masked
from repro.mapreduce.job import (MapReduceJob, Partitioner, Reducer,
                                 ShuffledData, run_job)

# Border-replication margin: replicating a hair MORE than the radius is
# always safe (extra copies can only re-find pairs that are already counted
# from both endpoints' zones), while replicating a hair less silently drops
# a pair. The epsilon absorbs f32-vs-f64 rounding in the edge tests, so the
# host and device engines agree exactly even for points that sit within one
# ulp of radius-from-edge.
REPLICA_EPS = 1e-6


@dataclasses.dataclass(frozen=True)
class ZonePartitioner(Partitioner):
    """Declination bands of height ``zone_height`` (default: the radius —
    the paper's "always favor larger blocks" choice, so border copies come
    only from adjacent zones). Points within ``radius`` (+eps) of a band
    edge are replicated into the neighboring band's bucket."""

    radius: float
    zone_height: float = 0.0

    @property
    def height(self) -> float:
        return self.zone_height or max(self.radius, 1e-4)

    def n_partitions(self, items):
        return sky.n_zones(self.height)

    def assign(self, items):
        dec = sky.dec_of(items)
        Z = self.n_partitions(items)
        return np.clip(((dec + np.pi / 2) / self.height).astype(np.int32),
                       0, Z - 1)

    def replicas(self, items, keys, n_parts):
        h, margin = self.height, self.radius + REPLICA_EPS
        dec = sky.dec_of(items)
        kf = keys.astype(np.float32)        # f32 edge math, same as device
        lo_edge = (dec - (kf * h - np.pi / 2)) <= margin
        hi_edge = (((kf + 1) * h - np.pi / 2) - dec) <= margin
        for k in range(n_parts):
            if k > 0:
                yield k - 1, np.flatnonzero((keys == k) & lo_edge)
            if k + 1 < n_parts:
                yield k + 1, np.flatnonzero((keys == k) & hi_edge)

    # device map stage: zone assignment and border replication as jax ops —
    # the whole (owned, lower-border, upper-border) entry stream has the
    # static length 3n, bucketed by one argsort in the engine.

    def _dec_device(self, items):
        return jnp.arcsin(jnp.clip(items[:, 2], -1.0, 1.0))

    def assign_device(self, items):
        Z = self.n_partitions(items)
        dec = self._dec_device(items)
        return jnp.clip(((dec + np.pi / 2) / self.height).astype(jnp.int32),
                        0, Z - 1)

    def sort_key_device(self, items):
        # z-order within each zone: tight per-tile z ranges for the banded
        # blocked reduce (order never changes results, only pruning power)
        return items[:, 2]

    def bucket_entries_device(self, items, keys, n_parts):
        h, margin = self.height, self.radius + REPLICA_EPS
        dec = self._dec_device(items)
        kf = keys.astype(jnp.float32)
        lo_edge = (dec - (kf * h - np.pi / 2)) <= margin
        hi_edge = (((kf + 1) * h - np.pi / 2) - dec) <= margin
        n = keys.shape[0]
        idx = jnp.arange(n, dtype=jnp.int32)
        dest = jnp.concatenate([keys, keys - 1, keys + 1])
        src = jnp.concatenate([idx, idx, idx])
        valid = jnp.concatenate([jnp.ones((n,), bool),
                                 lo_edge & (keys > 0),
                                 hi_edge & (keys + 1 < n_parts)])
        return dest, src, valid


@dataclasses.dataclass(frozen=True)
class PairCountReducer(Reducer):
    """Blockwise within-radius pair count per zone; finalize removes self
    pairs and the double-count."""

    radius: float
    use_pallas: bool | None = None

    def per_partition(self, owned_p, bucket_p):
        return pair_count(owned_p, bucket_p, float(np.cos(self.radius)),
                          use_pallas=self.use_pallas)

    def reduce_partitions(self, owned, bucket, n_owned, n_bucket):
        return pair_count_masked(owned, bucket, n_owned, n_bucket,
                                 float(np.cos(self.radius)),
                                 use_pallas=self.use_pallas)

    def reduce_traceable(self):
        from repro.kernels.zones_pairs.ops import masked_uses_pallas
        return masked_uses_pallas(self.use_pallas)

    def finalize(self, total, sd: ShuffledData):
        return (int(total) - int(sd.n_owned.sum())) // 2

    def flops(self, sd: ShuffledData):
        # per zone: C1*C2 dot products (2*3 FLOPs) + compares
        return sd.pair_cells * 8.0


def neighbor_search_job(radius_rad: float, *, zone_height: float = 0.0,
                        codec="identity", tile: int = 256,
                        use_pallas: bool | None = None,
                        partitioner: ZonePartitioner | None = None,
                        ) -> MapReduceJob:
    """The Neighbor Searching app as a composable job. Pass ``partitioner``
    explicitly to batch it with other jobs over one shuffle (``run_jobs``)."""
    part = partitioner or ZonePartitioner(radius_rad, zone_height)
    return MapReduceJob("neighbor_search", part,
                        PairCountReducer(radius_rad, use_pallas),
                        codec=codec, tile=tile)


def neighbor_search_count(xyz: np.ndarray, radius_rad: float, *, mesh=None,
                          compress_coords: bool = False,
                          use_pallas: bool | None = None,
                          tile: int = 256, zone_height: float = 0.0) -> int:
    """Deprecated wrapper (use ``neighbor_search_job`` + ``run_job``):
    total number of unordered neighbor pairs within radius."""
    warnings.warn("neighbor_search_count is deprecated; build a job with "
                  "neighbor_search_job() and execute it with run_job()",
                  DeprecationWarning, stacklevel=2)
    job = neighbor_search_job(radius_rad, zone_height=zone_height,
                              codec="int16" if compress_coords else "identity",
                              tile=tile, use_pallas=use_pallas)
    return run_job(job, xyz, mesh=mesh).output


def neighbor_pairs_dense(xyz: np.ndarray, radius_rad: float):
    """Small-N exact pair list (test oracle / example output)."""
    dots = xyz @ xyz.T
    np.fill_diagonal(dots, -2)
    i, j = np.where(dots >= np.cos(radius_rad))
    keep = i < j
    return np.stack([i[keep], j[keep]], axis=1)
