"""Token histogram (wordcount) — a non-astronomy job on the same engine.

Hadoop's canonical first job, run over the repo's LM data sources
(``data/pipeline.py``): map hashes each token to a partition, the shuffle
moves (optionally codec-compressed) token payloads, and the reduce bincounts
each partition's owned tokens — proving the Job API generalizes beyond the
paper's two astronomy apps while reusing the identical engine, codecs, and
``StageStats``/Amdahl accounting.

Codec note: tokens ride the wire as float32 scalars. ``identity`` is exact;
``Int16Codec(max_abs=vocab)`` is *lossless* for integer tokens whenever
``vocab < 32767`` (quantization error < 0.5, removed by the reducer's
round()) — the LZO trade at its best: half the shuffle bytes, zero error.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.mapreduce.codecs import Int16Codec
from repro.mapreduce.job import (HashPartitioner, JobResult, MapReduceJob,
                                 Reducer, ShuffledData, run_job)


@dataclasses.dataclass(frozen=True)
class TokenHistogramReducer(Reducer):
    """Per-partition bincount of owned tokens (padding rides as -1 on the
    host engine; masked by real counts on the device engine)."""

    vocab: int
    pad_value: float = -1.0

    def per_partition(self, owned_p, bucket_p):
        tok = jnp.round(owned_p[:, 0]).astype(jnp.int32)
        valid = (tok >= 0) & (tok < self.vocab)
        idx = jnp.clip(tok, 0, self.vocab - 1)
        return jnp.zeros((self.vocab,), jnp.int32).at[idx].add(
            valid.astype(jnp.int32))

    def reduce_partitions(self, owned, bucket, n_owned, n_bucket):
        tok = jnp.round(owned[..., 0]).astype(jnp.int32)      # [P, C1]
        valid = ((jnp.arange(tok.shape[1], dtype=jnp.int32)[None, :]
                  < n_owned[:, None])
                 & (tok >= 0) & (tok < self.vocab))
        idx = jnp.clip(tok, 0, self.vocab - 1)
        return jnp.zeros((self.vocab,), jnp.int32).at[idx.ravel()].add(
            valid.ravel().astype(jnp.int32))

    def finalize(self, total, sd: ShuffledData):
        return np.asarray(total, np.int64)

    def flops(self, sd: ShuffledData):
        return sd.owned_cells * 4.0


def token_histogram_job(vocab: int, *, n_partitions: int = 8,
                        codec="identity", tile: int = 256) -> MapReduceJob:
    """Wordcount as a composable job. ``codec="int16"`` halves shuffle bytes
    losslessly for ``vocab < 32767`` (see module docstring)."""
    if codec == "int16":
        codec = Int16Codec(max_abs=float(vocab))
    return MapReduceJob("token_histogram", HashPartitioner(n_partitions),
                        TokenHistogramReducer(vocab), codec=codec, tile=tile)


def token_histogram(tokens: np.ndarray, vocab: int, *, n_partitions: int = 8,
                    codec="identity", tile: int = 256,
                    mesh=None, engine: str = "auto") -> JobResult:
    """Count token occurrences across any token source block (e.g.
    ``SyntheticTokens.block`` / ``Pipeline.batch_at``). -> JobResult whose
    output is a [vocab] int64 count vector."""
    items = np.asarray(tokens).reshape(-1).astype(np.float32)
    job = token_histogram_job(vocab, n_partitions=n_partitions, codec=codec,
                              tile=tile)
    return run_job(job, items, mesh=mesh, engine=engine)
