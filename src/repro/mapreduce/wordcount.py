"""Token histogram (wordcount) — a non-astronomy job on the same engine.

Hadoop's canonical first job, run over the repo's LM data sources
(``data/pipeline.py``): map hashes each token to a partition, the shuffle
moves (optionally codec-compressed) token payloads, and the reduce bincounts
each partition's owned tokens — proving the Job API generalizes beyond the
paper's two astronomy apps while reusing the identical engine, codecs, and
``StageStats``/Amdahl accounting.

Wordcount is also the textbook map-side-combine job: its reduce is a
commutative-monoid fold over individual owned rows, so
``TokenHistogramReducer.combiner()`` returns a ``TokenCountCombiner`` and
the streaming executor (``mapreduce/executor.py``) pre-aggregates each split
to ``(token, count)`` rows BEFORE the shuffle — the wire then carries at
most ``min(split_rows, vocab)`` weighted entries instead of every token
occurrence, and only the combined [vocab] accumulator persists across
splits (out-of-core wordcount in O(vocab) device memory). The reducer
treats a second item column as an integer weight, so combined and raw
streams reduce through the same kernel and agree exactly.

Codec note: tokens ride the wire as float32 scalars. ``identity`` is exact;
``Int16Codec(max_abs=vocab)`` is *lossless* for integer tokens whenever
``vocab < 32767`` (quantization error < 0.5, removed by the reducer's
round()) — the LZO trade at its best: half the shuffle bytes, zero error.
(The combiner's count column is NOT generally in that domain — a count can
exceed ``vocab`` — which is why the executor only auto-derives combiners
for exact codecs.)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.mapreduce.codecs import Int16Codec
from repro.mapreduce.executor import Combiner
from repro.mapreduce.job import (HashPartitioner, JobResult, MapReduceJob,
                                 Reducer, ShuffledData, run_job)


@dataclasses.dataclass(frozen=True)
class TokenCountCombiner(Combiner):
    """Map-side combine for the token histogram: rewrite a raw ``[n, 1]``
    token split into ``[m, 2]`` (token, count) rows — ``m`` = distinct
    in-range tokens present — before map/shuffle; per-split histogram
    partials then tree-sum across splits (the base ``combine``)."""

    vocab: int
    name: str = "token_count"

    def precombine(self, items: np.ndarray) -> np.ndarray:
        tok = np.rint(np.asarray(items, np.float64).reshape(-1)
                      ).astype(np.int64)
        tok = tok[(tok >= 0) & (tok < self.vocab)]
        counts = np.bincount(tok, minlength=self.vocab)
        nz = np.flatnonzero(counts)
        return np.stack([nz, counts[nz]], axis=1).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class TokenHistogramReducer(Reducer):
    """Per-partition bincount of owned tokens (padding rides as -1 on the
    host engine; masked by real counts on the device engine). Rows may
    carry a second column as an integer weight — that is how the map-side
    combiner's (token, count) streams reduce through the same kernel."""

    vocab: int
    pad_value: float = -1.0
    cost_basis = "rows"   # bincount is linear in owned rows, not pair cells

    @staticmethod
    def _weights(owned, valid):
        if owned.shape[-1] > 1:
            return valid * jnp.round(owned[..., 1]).astype(jnp.int32)
        return valid

    def per_partition(self, owned_p, bucket_p):
        tok = jnp.round(owned_p[:, 0]).astype(jnp.int32)
        valid = ((tok >= 0) & (tok < self.vocab)).astype(jnp.int32)
        idx = jnp.clip(tok, 0, self.vocab - 1)
        return jnp.zeros((self.vocab,), jnp.int32).at[idx].add(
            self._weights(owned_p, valid))

    def reduce_partitions(self, owned, bucket, n_owned, n_bucket):
        tok = jnp.round(owned[..., 0]).astype(jnp.int32)      # [P, C1]
        valid = ((jnp.arange(tok.shape[1], dtype=jnp.int32)[None, :]
                  < n_owned[:, None])
                 & (tok >= 0) & (tok < self.vocab)).astype(jnp.int32)
        idx = jnp.clip(tok, 0, self.vocab - 1)
        return jnp.zeros((self.vocab,), jnp.int32).at[idx.ravel()].add(
            self._weights(owned, valid).ravel())

    def finalize(self, total, sd: ShuffledData):
        return np.asarray(total, np.int64)

    def flops(self, sd: ShuffledData):
        return sd.owned_cells * 4.0

    def combiner(self):
        return TokenCountCombiner(self.vocab)


def token_histogram_job(vocab: int, *, n_partitions: int = 8,
                        codec="identity", tile: int = 256) -> MapReduceJob:
    """Wordcount as a composable job. ``codec="int16"`` halves shuffle bytes
    losslessly for ``vocab < 32767`` (see module docstring)."""
    if codec == "int16":
        codec = Int16Codec(max_abs=float(vocab))
    return MapReduceJob("token_histogram", HashPartitioner(n_partitions),
                        TokenHistogramReducer(vocab), codec=codec, tile=tile)


def token_histogram(tokens: np.ndarray, vocab: int, *, n_partitions: int = 8,
                    codec="identity", tile: int = 256,
                    mesh=None, engine: str = "auto") -> JobResult:
    """Count token occurrences across any token source block (e.g.
    ``SyntheticTokens.block`` / ``Pipeline.batch_at``). -> JobResult whose
    output is a [vocab] int64 count vector."""
    items = np.asarray(tokens).reshape(-1).astype(np.float32)
    job = token_histogram_job(vocab, n_partitions=n_partitions, codec=codec,
                              tile=tile)
    return run_job(job, items, mesh=mesh, engine=engine)
