from repro.mapreduce.api import bucket_by_zone, sharded_zone_reduce, ZonedData
from repro.mapreduce.zones import neighbor_search_count, neighbor_pairs_dense
from repro.mapreduce.stats import neighbor_statistics
