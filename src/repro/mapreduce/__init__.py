"""Composable MapReduce on a jax mesh: a four-stage streaming pipeline.

Stage plugins (``Partitioner`` / ``Combiner`` / ``ShuffleCodec`` /
``Reducer``) compose into a ``MapReduceJob`` executed by the split-streaming
executor (``executor.py``): a ``SplitSource`` feeds HDFS-block-analog
catalog splits through map -> combine -> shuffle -> reduce, with a prefetch
thread double-buffering the next split's fetch + host->device transfer under
the current split's compute. Monoid reducers (wordcount) get Hadoop-style
map-side combine — only combined accumulators persist across splits, so
catalogs larger than device memory stream at full speed; cross-row reducers
(pair counting) accumulate wire-dtype shuffle streams and reduce once at the
end. ``run_job``/``run_jobs`` are the one-split special case of the same
code path.

Two engines run each split (``job.py``): ``device`` (the default —
wire-dtype shuffle, capacity tiers, masked batched reduce; under a
``data``-axis mesh the tiers shard across the axis and tier partials combine
with a psum) and ``host`` (the numpy + ``lax.map`` oracle, bit-identical for
exact codecs on or off mesh, streaming or monolithic). Every run emits
``StageStats`` for per-stage Amdahl accounting, including the
exposed-vs-hidden split I/O decomposition (``fetch_wall_s`` /
``overlap_hidden_s``). The paper's two apps (``zones.py``, ``stats.py``) and
the wordcount job (``wordcount.py``) are thin definitions on this API;
``api.py`` keeps the legacy surface.
"""
# Job API (the composable surface)
from repro.mapreduce.codecs import (EncodedShuffle, IdentityCodec,
                                    Int8BlockCodec, Int16Codec, ShuffleCodec,
                                    available_codecs, get_codec,
                                    register_codec)
from repro.mapreduce.instrumentation import (RequestStats, StageStats,
                                             latency_summary)
from repro.mapreduce.job import (DeviceShuffledData, HashPartitioner,
                                 JobResult, MappedSplit, MapReduceJob,
                                 Partitioner, Reducer, ResidentCatalog,
                                 ShuffledData, StreamSummary, TierData,
                                 concat_mapped, group_batch_compatible,
                                 map_split_device, plan_tiers, reduce_stage,
                                 resolve_auto_job, run_job, run_jobs,
                                 shuffle_once, shuffle_reduce_device,
                                 shuffle_reduce_device_streamed,
                                 shuffle_signature, shuffle_stage)
from repro.mapreduce.executor import (Combiner, JobDeadlineExceeded,
                                      LaneCancelled, LanePool,
                                      run_job_streaming, run_jobs_streaming)
from repro.mapreduce.spill import (SpillConfig, SpilledChunk, SpillStore,
                                   mapped_to_host, mapped_wire_nbytes,
                                   plan_bounds)
from repro.mapreduce.zones import (PairCountReducer, ZonePartitioner,
                                   neighbor_pairs_dense, neighbor_search_job)
from repro.mapreduce.stats import PairHistReducer, neighbor_statistics_job
from repro.mapreduce.wordcount import (TokenCountCombiner,
                                       TokenHistogramReducer, token_histogram,
                                       token_histogram_job)

# Legacy surface (deprecated wrappers; kept for compatibility)
from repro.mapreduce.api import ZonedData, bucket_by_zone, sharded_zone_reduce
from repro.mapreduce.zones import neighbor_search_count
from repro.mapreduce.stats import neighbor_statistics
