"""Composable MapReduce on a jax mesh.

Stage plugins (``Partitioner`` / ``ShuffleCodec`` / ``Reducer``) compose into
a ``MapReduceJob`` run by one of two engines (``job.py``): ``device`` (the
default — wire-dtype shuffle, capacity tiers, masked batched reduce; under a
``data``-axis mesh the tiers shard across the axis and tier partials combine
with a psum) and ``host`` (the numpy + ``lax.map`` oracle, bit-identical for
exact codecs on or off mesh). Every run emits ``StageStats`` for per-stage
Amdahl accounting. The paper's two apps (``zones.py``, ``stats.py``) and the
wordcount job (``wordcount.py``) are thin definitions on this API;
``api.py`` keeps the legacy surface.
"""
# Job API (the composable surface)
from repro.mapreduce.codecs import (EncodedShuffle, IdentityCodec,
                                    Int8BlockCodec, Int16Codec, ShuffleCodec,
                                    available_codecs, get_codec,
                                    register_codec)
from repro.mapreduce.instrumentation import StageStats
from repro.mapreduce.job import (DeviceShuffledData, HashPartitioner,
                                 JobResult, MapReduceJob, Partitioner,
                                 Reducer, ShuffledData, TierData, plan_tiers,
                                 reduce_stage, run_job, run_jobs,
                                 shuffle_stage)
from repro.mapreduce.zones import (PairCountReducer, ZonePartitioner,
                                   neighbor_pairs_dense, neighbor_search_job)
from repro.mapreduce.stats import PairHistReducer, neighbor_statistics_job
from repro.mapreduce.wordcount import (TokenHistogramReducer, token_histogram,
                                       token_histogram_job)

# Legacy surface (deprecated wrappers; kept for compatibility)
from repro.mapreduce.api import ZonedData, bucket_by_zone, sharded_zone_reduce
from repro.mapreduce.zones import neighbor_search_count
from repro.mapreduce.stats import neighbor_statistics
