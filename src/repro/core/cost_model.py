"""HLO-calibrated cost model: predicted stage walls drive planning knobs.

The paper's argument is a balance calculation — measure where cycles and
bytes go, then size the system so no knob is the accidental bottleneck.
This module replaces the hand-tuned planning constants with that loop:

1. **Census** (`stage_census`): jit + lower + compile a stage callable at
   abstract shapes and run the `hlo_analysis` census over the optimized HLO
   — analytic dot-FLOPs, elementwise FLOPs and HBM bytes per candidate
   configuration. The pair kernels are unrolled broadcast sums (the bit
   parity contract forbids `dot_general`), so their arithmetic shows up in
   ``ew_flops``, not ``flops``.
2. **Calibration** (`CostModel.calibrate`): a short one-time replay of five
   micro-shapes of the blocked chunk kernel, timed with the same
   warmup/best-of-N convention as ``benchmarks/paper_benches._t``, fitted to
   ``wall ~= flops/F + bytes/B + dispatch`` and cached on disk per backend
   fingerprint (backend | device kind | jax version | cpu count). The replay
   NEVER runs implicitly: plain ``get_cost_model()`` loads the disk cache if
   the fingerprint matches and otherwise falls back to analytic per-backend
   defaults, so planning never poisons bench timings. Calibration is skipped
   outright (analytic defaults, ``calibrated=False``) when the process has
   <2 CPUs or ``REPRO_NO_CALIBRATE=1``.
3. **Prediction** (`predict_stage_wall`, `argmin`): seconds per stage from
   the fitted rates, and an argmin planner over candidate configurations.

Consumers: ``plan_tiers(tier_cost=...)`` (predicted tier walls instead of
padded-cell counts), the blocked engine's chunk shape
(``REPRO_AUTO_CHUNK=1``), ``codec="auto"``/``tile="auto"`` job knobs, split
row sizing and the spill tier's range count. Every auto path only changes
shapes/choices, never arithmetic — auto-planned runs are bit-identical to
manual configs for exact codecs (masked kernels handle any geometry).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time

import numpy as np

from repro.core.hlo_analysis import HLOAnalysis, analyze_hlo

# Analytic per-backend default rates used when no calibration is available:
# (effective flop/s, effective HBM bytes/s, per-dispatch overhead seconds).
# They only need to RANK candidate shapes sensibly; absolute accuracy is a
# calibrated-backend property (the <=2x acceptance bound applies there).
DEFAULT_RATES = {
    "cpu": (2.0e10, 1.0e10, 5.0e-5),
    "gpu": (1.0e13, 8.0e11, 1.5e-5),
    "tpu": (2.0e13, 8.0e11, 5.0e-6),
}

# Calibration micro-shapes: (tm, tn, b0) chunk geometries of the blocked
# pair kernel. The first is tiny (dispatch-overhead anchor); the rest span
# the candidate chunk space the auto chunk chooser ranks over.
CALIBRATION_SHAPES = ((8, 8, 8), (32, 32, 256), (64, 64, 256),
                      (64, 64, 512), (128, 128, 512))

DEFAULT_CHUNK = (64, 64, 512)      # the hand-tuned blocked chunk shape
TILE_CANDIDATES = (64, 128, 256, 512)
# fixed per-tier dispatch chain charged under the "rows" cost basis: each
# tier is its own decode + reduce + accumulator-output sequence, and for
# linear reducers that overhead dominates the (tiny) arithmetic saved
_TIER_DISPATCHES = 8.0


def backend_fingerprint() -> str:
    import jax
    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "unknown")
    return (f"{jax.default_backend()}|{kind}|jax{jax.__version__}"
            f"|cpus{os.cpu_count() or 1}")


def calibration_enabled() -> bool:
    """Replay is allowed: >=2 CPUs and not opted out via env."""
    if os.environ.get("REPRO_NO_CALIBRATE") == "1":
        return False
    return (os.cpu_count() or 1) >= 2


def cache_dir() -> str:
    return (os.environ.get("REPRO_CACHE_DIR")
            or os.path.join(os.path.expanduser("~"), ".cache", "repro"))


def cache_path(fingerprint: str) -> str:
    tag = hashlib.sha1(fingerprint.encode()).hexdigest()[:12]
    return os.path.join(cache_dir(), f"cost_model-{tag}.json")


@dataclasses.dataclass(frozen=True)
class StageCost:
    """Analytic cost of one stage configuration (census units)."""
    flops: float                 # dot + elementwise FLOPs
    hbm_bytes: float = 0.0
    n_dispatch: float = 1.0

    @classmethod
    def from_analysis(cls, a: HLOAnalysis, n_dispatch: float = 1.0):
        return cls(a.flops + a.ew_flops, a.hbm_bytes, n_dispatch)


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    """Effective rates for one backend fingerprint."""
    fingerprint: str
    flops_per_s: float
    bytes_per_s: float
    dispatch_s: float
    calibrated: bool = False
    # per-probe replay rows: (tm, tn, b0, wall_s, flops, hbm_bytes)
    probes: tuple = ()


def stage_census(fn, *args) -> HLOAnalysis:
    """Compile ``fn`` at the given (abstract or concrete) arguments and run
    the HLO census over the optimized module."""
    import jax
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(hlo)


def _time(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Seconds per call, same convention as ``paper_benches._t``: ``warmup``
    untimed calls (compile + cache warm), then the mean of ``reps``."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def _probe_args(tm: int, tn: int, b0: int, seed: int = 0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((b0, tm, 3)).astype(np.float32)
    b = rng.standard_normal((b0, tn, 3)).astype(np.float32)
    a /= np.linalg.norm(a, axis=-1, keepdims=True)
    b /= np.linalg.norm(b, axis=-1, keepdims=True)
    na = np.full(b0, tm, np.int32)
    nb = np.full(b0, tn, np.int32)
    return (jnp.asarray(a), jnp.asarray(b), jnp.asarray(na), jnp.asarray(nb),
            jnp.float32(0.99))


def _run_replay(shapes=CALIBRATION_SHAPES):
    """Measure + census the blocked chunk kernel at the micro-shapes.
    Returns probe rows (tm, tn, b0, wall_s, flops, hbm_bytes)."""
    from repro.kernels.zones_pairs.blocked import _count_chunk
    rows = []
    for (tm, tn, b0) in shapes:
        args = _probe_args(tm, tn, b0)
        wall = _time(_count_chunk, *args)
        a = stage_census(_count_chunk, *args)
        rows.append((tm, tn, b0, float(wall),
                     float(a.flops + a.ew_flops), float(a.hbm_bytes)))
    return tuple(rows)


def _fit_profile(fingerprint: str, probes) -> BackendProfile:
    """wall ~= flops/F + bytes/B + c, nonnegative. The tiny anchor probe
    pins the dispatch overhead; a least-squares fit over the residuals gives
    the rates, with a single-rate fallback if the fit goes non-positive."""
    walls = np.array([p[3] for p in probes], np.float64)
    flops = np.array([p[4] for p in probes], np.float64)
    byts = np.array([p[5] for p in probes], np.float64)
    dispatch = float(max(walls.min(), 1e-7))
    resid = np.maximum(walls - dispatch, 1e-9)
    big = flops > flops.min()       # drop the anchor from the rate fit
    if big.sum() >= 2:
        A = np.stack([flops[big], byts[big]], axis=1)
        coef, *_ = np.linalg.lstsq(A, resid[big], rcond=None)
    else:
        coef = np.zeros(2)
    if coef[0] <= 0 or coef[1] <= 0:
        # degenerate fit: charge everything to both rates proportionally
        per = resid.sum()
        coef = np.array([per / max(flops.sum(), 1.0),
                         per / max(byts.sum(), 1.0)])
    return BackendProfile(fingerprint, 1.0 / float(coef[0]),
                          1.0 / float(coef[1]), dispatch,
                          calibrated=True, probes=tuple(probes))


def _default_profile(fingerprint: str) -> BackendProfile:
    backend = fingerprint.split("|", 1)[0]
    f, b, d = DEFAULT_RATES.get(backend, DEFAULT_RATES["cpu"])
    return BackendProfile(fingerprint, f, b, d, calibrated=False)


def _load_cached(fingerprint: str) -> BackendProfile | None:
    path = cache_path(fingerprint)
    try:
        with open(path) as fh:
            d = json.load(fh)
    except (OSError, ValueError):
        return None
    if d.get("fingerprint") != fingerprint:   # stale: backend changed
        return None
    try:
        return BackendProfile(
            d["fingerprint"], float(d["flops_per_s"]),
            float(d["bytes_per_s"]), float(d["dispatch_s"]),
            calibrated=True,
            probes=tuple(tuple(p) for p in d.get("probes", ())))
    except (KeyError, TypeError, ValueError):
        return None


def _save_cache(profile: BackendProfile) -> None:
    os.makedirs(cache_dir(), exist_ok=True)
    path = cache_path(profile.fingerprint)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump({"fingerprint": profile.fingerprint,
                   "flops_per_s": profile.flops_per_s,
                   "bytes_per_s": profile.bytes_per_s,
                   "dispatch_s": profile.dispatch_s,
                   "probes": [list(p) for p in profile.probes]}, fh)
    os.replace(tmp, path)


class CostModel:
    """Predicted stage walls + argmin planning over one backend profile."""

    def __init__(self, profile: BackendProfile):
        self.profile = profile

    # -- construction -------------------------------------------------------

    @classmethod
    def load(cls, calibrate: bool = False) -> "CostModel":
        fp = backend_fingerprint()
        prof = _load_cached(fp)
        if prof is None and calibrate and calibration_enabled():
            prof = _fit_profile(fp, _run_replay())
            _save_cache(prof)
        if prof is None:
            prof = _default_profile(fp)
        return cls(prof)

    def calibrate(self) -> "CostModel":
        """Force the replay (subject to the skip guards) and re-fit."""
        fp = backend_fingerprint()
        if not calibration_enabled():
            return CostModel(_default_profile(fp))
        prof = _fit_profile(fp, _run_replay())
        _save_cache(prof)
        self.profile = prof
        return self

    # -- prediction ---------------------------------------------------------

    def predict_wall(self, cost: StageCost) -> float:
        p = self.profile
        return (cost.flops / p.flops_per_s + cost.hbm_bytes / p.bytes_per_s
                + cost.n_dispatch * p.dispatch_s)

    def predict_stage_wall(self, config, *args) -> float:
        """Seconds for one stage configuration. ``config`` may be a
        ``StageCost``, an ``HLOAnalysis``, or a stage callable (censused at
        ``*args``)."""
        if callable(config):
            config = StageCost.from_analysis(stage_census(config, *args))
        elif isinstance(config, HLOAnalysis):
            config = StageCost.from_analysis(config)
        return self.predict_wall(config)

    def argmin(self, candidates):
        """``candidates``: iterable of (key, StageCost). Returns the
        (key, predicted_wall) pair with the smallest wall; first wins ties."""
        best = None
        for key, cost in candidates:
            w = self.predict_wall(cost)
            if best is None or w < best[1]:
                best = (key, w)
        if best is None:
            raise ValueError("argmin over no candidates")
        return best

    # -- consumer choosers --------------------------------------------------

    def tier_cost_fn(self, *, d: int = 3, basis: str = "pairs",
                     flops_per_cell: float = 8.0,
                     bytes_per_cell: float = 4.0):
        """Vectorized ``f(Pt, C1, C2) -> predicted tier walls`` for
        ``plan_tiers(tier_cost=...)``. Phantom shards stay charged because
        Pt is the padded partition count.

        ``basis`` follows the reducer's declared ``cost_basis``:

        - ``"pairs"`` (cross-row reducers): work is quadratic in the padded
          score cells (Pt*C1*C2) plus input HBM traffic and per-chunk
          dispatch overhead.
        - ``"rows"`` (monoid/bincount-style reducers): work is LINEAR in
          the padded owned rows (Pt*C1) — tiering buys almost no arithmetic
          back, so each extra tier is mostly its fixed dispatch-chain
          overhead (decode + reduce + accumulator output). The per-tier
          constant makes the planner prefer few tiers / coarse tiles here.
        """
        p = self.profile
        ctm, ctn, cb0 = DEFAULT_CHUNK
        chunk_cells = float(ctm * ctn * cb0)

        def cost(Pt, C1, C2):
            Pt = np.asarray(Pt, np.float64)
            C1 = np.asarray(C1, np.float64)
            C2 = np.asarray(C2, np.float64)
            io_bytes = Pt * (C1 + C2) * d * 4.0
            if basis == "rows":
                rows = Pt * C1
                flops = rows * 4.0
                ndisp = np.maximum(rows / chunk_cells, 1.0) + _TIER_DISPATCHES
                return (flops / p.flops_per_s + io_bytes / p.bytes_per_s
                        + ndisp * p.dispatch_s)
            cells = Pt * C1 * C2
            flops = cells * flops_per_cell
            byts = cells * bytes_per_cell + io_bytes
            ndisp = np.maximum(cells / chunk_cells, 1.0)
            return (flops / p.flops_per_s + byts / p.bytes_per_s
                    + ndisp * p.dispatch_s)

        return cost

    def plan_shuffle(self, n_owned, n_bucket, pad_partitions_to: int = 1,
                     *, d: int = 3, basis: str = "pairs", max_tiers: int = 3,
                     candidates=TILE_CANDIDATES):
        """Pick (tile, tier plan) minimizing the predicted reduce wall.
        Each candidate tile is planned with the predicted-wall tier cost
        (``basis`` per the reducer's ``cost_basis`` — see ``tier_cost_fn``);
        ties keep the earliest candidate. Returns (tile, plan, wall_s)."""
        from repro.mapreduce.job import plan_tiers
        f = self.tier_cost_fn(d=d, basis=basis)
        best = None
        for tile in candidates:
            plan = plan_tiers(n_owned, n_bucket, tile, max_tiers=max_tiers,
                              pad_partitions_to=pad_partitions_to,
                              tier_cost=f)
            Pt = np.array([-(-len(ids) // pad_partitions_to)
                           * pad_partitions_to for ids, _, _ in plan])
            C1 = np.array([c1 for _, c1, _ in plan])
            C2 = np.array([c2 for _, _, c2 in plan])
            wall = float(np.sum(f(Pt, C1, C2)))
            if best is None or wall < best[2]:
                best = (tile, plan, wall)
        return best

    def choose_codec(self, *, d: int = 3, candidates=None,
                     n_items: float = 1e6) -> str:
        """Exact codecs only — codec choice must never change arithmetic.
        Ranked by predicted shuffle wire traffic + decode cost."""
        from repro.mapreduce.codecs import available_codecs, get_codec
        names = candidates if candidates is not None else available_codecs()
        exact = [n for n in names if get_codec(n).exact]
        if not exact:
            raise ValueError("no exact codec available for codec='auto'")
        key, _ = self.argmin(
            (n, StageCost(
                flops=0.0 if n == "identity" else 2.0 * n_items * d,
                hbm_bytes=3.0 * n_items
                * get_codec(n).device_bytes_per_item(d)))
            for n in exact)
        return key

    def choose_blocked_chunk(self, default=DEFAULT_CHUNK):
        """(TM, TN, B0) for the blocked engine. With calibration probes:
        rank measured per-cell walls amortized over a nominal workload (the
        replay-measured tile chooser); otherwise keep the hand-tuned
        default — on an uncalibrated backend the model has no basis to
        deviate."""
        probes = [p for p in self.profile.probes
                  if p[0] * p[1] * p[2] >= 32 * 32 * 256]   # skip the anchor
        if not self.profile.calibrated or not probes:
            return default
        W = float(2 ** 27)        # nominal score cells per partition pair
        disp = self.profile.dispatch_s

        def wall(p):
            tm, tn, b0, w, _, _ = p
            cells = float(tm * tn * b0)
            return W * (w / cells) + np.ceil(W / cells) * disp

        best = min(probes, key=wall)
        if wall(best) >= wall(next((p for p in probes
                                    if tuple(p[:3]) == default), best)):
            return default        # ties / default measured best: keep it
        return (int(best[0]), int(best[1]), int(best[2]))

    def choose_split_rows(self, n_rows: int, *, d: int = 3,
                          bytes_per_row: float | None = None,
                          max_split_bytes: float = 128e6) -> int:
        """Rows per split for streaming: large enough that per-split fixed
        overhead (~8 dispatches) stays under ~5% of the per-split wall,
        small enough that a split's raw bytes fit the working-set cap."""
        p = self.profile
        bpr = bytes_per_row if bytes_per_row is not None else 4.0 * d
        row_wall = 3.0 * bpr / p.bytes_per_s + 8.0 * d / p.flops_per_s
        fixed = 8.0 * p.dispatch_s
        lo = int(np.ceil(20.0 * fixed / max(row_wall, 1e-18)))
        hi = max(int(max_split_bytes / max(bpr, 1.0)), 1)
        return int(np.clip(min(lo, hi), 1, max(n_rows, 1)))

    def choose_spill_ranges(self, est_total_bytes: float,
                            budget_bytes: float, P: int,
                            max_ranges: int = 256) -> int:
        """Smallest range count whose per-range read-back fits inside half
        the budget (the spill runtime's flush watermark); fewer ranges mean
        fewer replans, each costing fixed overhead."""
        cap = max(1, min(int(P), int(max_ranges)))
        half = max(budget_bytes / 2.0, 1.0)
        need = int(np.ceil(max(est_total_bytes, 0.0) / half))
        return int(np.clip(need, 1, cap))


_MODEL_CACHE: dict[str, CostModel] = {}


def get_cost_model(calibrate: bool | None = None) -> CostModel:
    """Process-cached model for the current backend. ``calibrate=None``
    (default) never runs the replay — it loads the disk cache when the
    fingerprint matches, else analytic defaults. Pass ``calibrate=True`` (or
    set ``REPRO_CALIBRATE=1``) to run the one-time replay (still subject to
    the <2-CPU / ``REPRO_NO_CALIBRATE`` guards)."""
    want = bool(calibrate) or os.environ.get("REPRO_CALIBRATE") == "1"
    fp = backend_fingerprint()
    m = _MODEL_CACHE.get(fp)
    if m is None or (want and not m.profile.calibrated):
        m = CostModel.load(calibrate=want)
        _MODEL_CACHE[fp] = m
    return m


def reset_cost_model() -> None:
    """Drop process-cached models (tests; does not touch the disk cache)."""
    _MODEL_CACHE.clear()
