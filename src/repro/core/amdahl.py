"""Amdahl-number / roofline analysis (the paper's Table 4, recast for TPU v5e).

The paper measures, per Hadoop task, instruction rate vs disk and network I/O and
derives "Amdahl numbers" (bits of I/O per instruction) — concluding the CPU is the
bottleneck and a balanced node needs 4 cores. We derive the same three-resource balance
for every (arch x shape x mesh) from the compiled dry-run artifact:

    compute term    = HLO_FLOPs   / (chips * 197e12 FLOP/s bf16)
    memory term     = HLO_bytes   / (chips * 819e9  B/s HBM)
    collective term = coll_bytes  / (chips * n_links * 50e9 B/s ICI)  (per class)

and report the dominant term, the useful-FLOP ratio MODEL_FLOPS / HLO_FLOPS, and the
"chips to balance" figure (the paper's four-core estimate: how much compute per chip
the observed I/O pattern could actually feed).
"""
from __future__ import annotations

import dataclasses

# TPU v5e-class hardware constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS_PER_CHIP = 4       # 2D torus (single-pod mesh)
CROSS_POD_BW = 25e9          # effective per-chip cross-pod bandwidth (DCI-limited)


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    hbm_bytes: float
    coll_bytes_intra: float
    coll_bytes_cross: float
    chips: int
    model_flops: float = 0.0
    chip_w: float = 0.0          # watts per chip (0 = no power accounting)

    @classmethod
    def from_stage_bytes(cls, *, flops: float, hbm_bytes: float,
                         wire_bytes: float, chips: int = 1,
                         model_flops: float = 0.0,
                         chip_w: float = 0.0) -> "RooflineTerms":
        """Build terms from per-stage MapReduce accounting (StageStats):
        reduce FLOPs -> compute, map+reduce bytes -> memory, shuffle wire
        bytes -> the intra-pod collective term (the paper's network I/O).
        ``chip_w`` carries per-chip watts into the balance estimate."""
        return cls(flops=flops, hbm_bytes=hbm_bytes,
                   coll_bytes_intra=wire_bytes, coll_bytes_cross=0.0,
                   chips=chips, model_flops=model_flops or flops,
                   chip_w=chip_w)

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        t_intra = self.coll_bytes_intra / (self.chips * ICI_BW * ICI_LINKS_PER_CHIP)
        t_cross = self.coll_bytes_cross / (self.chips * CROSS_POD_BW)
        return t_intra + t_cross

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap bound: max of the three terms (perfect overlap ideal)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the ideal-overlap bound:
        MODEL_FLOPS / (chips * peak * step_time)."""
        if not self.model_flops or not self.step_time:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * self.step_time)

    @property
    def mfu_bound(self) -> float:
        return self.roofline_fraction

    def amdahl_numbers(self) -> dict:
        """The paper's AD / ADN analogues: bytes of I/O per FLOP vs machine balance.

        machine balance (HBM): 819/197e3 = 4.16 mB/FLOP; a workload whose
        bytes-per-flop exceeds the machine's is I/O(memory)-bound, exactly the
        paper's 'Amdahl number > 1' test.
        """
        bpf_mem = self.hbm_bytes / self.flops if self.flops else 0.0
        bpf_net = ((self.coll_bytes_intra + self.coll_bytes_cross) / self.flops
                   if self.flops else 0.0)
        machine_mem = HBM_BW / PEAK_FLOPS
        machine_net = ICI_BW * ICI_LINKS_PER_CHIP / PEAK_FLOPS
        return {
            "AD": bpf_mem / machine_mem if machine_mem else 0.0,     # >1 => mem-bound
            "ADN": ((bpf_mem / machine_mem) + (bpf_net / machine_net)
                    if machine_mem else 0.0),
            "bytes_per_flop_mem": bpf_mem,
            "bytes_per_flop_net": bpf_net,
        }

    def chips_to_balance(self) -> float:
        """Chips needed so compute time matches the I/O time at this workload shape
        (the paper's 'four Atom cores' estimate, inverted for chips)."""
        t_io = max(self.t_memory, self.t_collective)
        if t_io <= 0:
            return float(self.chips)
        return self.chips * self.t_compute / t_io

    @property
    def power_w(self) -> float:
        """Provisioned draw of the configured mesh (chips x watts/chip)."""
        return self.chips * self.chip_w

    def balance_watts(self) -> float:
        """The balance point priced in watts: the paper answers 'how many
        cores make a balanced node' (four Atom cores); with a power term
        the same estimate reads as the compute draw this workload's I/O
        pattern can keep fed. 0.0 when no ``chip_w`` was supplied."""
        return self.chips_to_balance() * self.chip_w

    def to_dict(self) -> dict:
        d = {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes_intra": self.coll_bytes_intra,
            "coll_bytes_cross": self.coll_bytes_cross,
            "chips": self.chips, "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "step_time_s": self.step_time,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }
        d.update(self.amdahl_numbers())
        d["chips_to_balance"] = self.chips_to_balance()
        d["chip_w"] = self.chip_w
        d["balance_watts"] = self.balance_watts()
        return d


def model_flops_train(n_params_active: int, tokens: int) -> float:
    """6 N D for a training step (fwd+bwd)."""
    return 6.0 * n_params_active * tokens


def model_flops_prefill(n_params_active: int, tokens: int) -> float:
    return 2.0 * n_params_active * tokens


def model_flops_decode(n_params_active: int, tokens: int) -> float:
    return 2.0 * n_params_active * tokens
