"""Gradient bucketing — the paper's BufferedOutputStream analogue.

Hadoop paid a high fixed cost (JNI entry) per tiny HDFS write; buffering output into
large batches bought a 2x speedup. The TPU analogue of the fixed per-call cost is the
per-HLO-op dispatch/fusion boundary and per-collective launch: a model with hundreds of
parameter tensors otherwise emits hundreds of small optimizer-update ops and small
reduce-scatters. Bucketing flattens the gradient pytree into a few large 1D buffers
(per dtype, capped at ``bucket_bytes``), so the optimizer update and any explicit sync
run over O(few) fused ops. ``tests/test_buckets.py`` property-checks the roundtrip.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.sharding import current_mesh


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    # per-leaf (bucket index, offset)
    assign: tuple[tuple[int, int], ...]
    bucket_sizes: tuple[int, ...]          # padded to mesh divisibility
    pad_multiple: int


def make_plan(tree, bucket_bytes: int = 1 << 28, pad_multiple: int = 1) -> BucketPlan:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(l.shape for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = tuple(int(np.prod(s)) for s in shapes)
    assign = []
    bucket_sizes: list[int] = []
    cur = -1
    cur_bytes = 0
    for l, n in zip(leaves, sizes):
        nbytes = n * l.dtype.itemsize
        if cur < 0 or cur_bytes + nbytes > bucket_bytes:
            cur += 1
            bucket_sizes.append(0)
            cur_bytes = 0
        assign.append((cur, bucket_sizes[cur]))
        bucket_sizes[cur] += n
        cur_bytes += nbytes
    padded = tuple(((s + pad_multiple - 1) // pad_multiple) * pad_multiple
                   for s in bucket_sizes)
    return BucketPlan(treedef, shapes, dtypes, sizes, tuple(assign), padded,
                      pad_multiple)


def _bucket_sharding():
    from repro.parallel.sharding import current_manual_axes, sharding_mesh
    mesh = current_mesh()
    if mesh is None:
        return None
    axes = tuple(a for a in mesh.axis_names if a not in current_manual_axes())
    if not axes:
        return None
    return NamedSharding(sharding_mesh(), P(axes))


def flatten(plan: BucketPlan, tree, dtype=jnp.float32) -> list[jax.Array]:
    """Pack a pytree (matching the plan) into 1D buckets (cast to ``dtype``)."""
    leaves = jax.tree.flatten(tree)[0]
    shard = _bucket_sharding()
    buckets = []
    per_bucket: dict[int, list] = {}
    for (bi, off), l in zip(plan.assign, leaves):
        per_bucket.setdefault(bi, []).append(l.reshape(-1).astype(dtype))
    for bi in range(len(plan.bucket_sizes)):
        v = jnp.concatenate(per_bucket[bi])
        pad = plan.bucket_sizes[bi] - v.shape[0]
        if pad:
            v = jnp.pad(v, (0, pad))
        if shard is not None:
            v = jax.lax.with_sharding_constraint(v, shard)
        buckets.append(v)
    return buckets


def unflatten(plan: BucketPlan, buckets: list[jax.Array]):
    """Unpack buckets back into the original pytree (original dtypes/shapes)."""
    leaves = []
    cursor: dict[int, int] = {}
    for (bi, off), shape, dt, n in zip(plan.assign, plan.shapes, plan.dtypes,
                                       plan.sizes):
        piece = jax.lax.dynamic_slice_in_dim(buckets[bi], off, n, axis=0)
        leaves.append(piece.reshape(shape).astype(dt))
    return jax.tree.unflatten(plan.treedef, leaves)


def zeros_like_buckets(plan: BucketPlan, dtype=jnp.float32):
    shard = _bucket_sharding()
    out = []
    for s in plan.bucket_sizes:
        z = jnp.zeros((s,), dtype)
        if shard is not None:
            z = jax.lax.with_sharding_constraint(z, shard)
        out.append(z)
    return out
