"""jax version compatibility shims (single home for try/except-API code).

The repo targets current jax but must degrade gracefully on older releases
(no ``jax.shard_map``, no ``jax.sharding.AxisType``, no ``jax.lax.axis_size``).
Only fully-manual shard_map regions can be expressed on old jax; callers that
need partial-manual axes (``axis_names`` a strict subset of the mesh) should
keep using ``jax.shard_map`` directly and document the version floor.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis_types when supported."""
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) *
                             len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names):
    """jax.shard_map, falling back to jax.experimental.shard_map (which is
    fully manual: the fallback treats every mesh axis as manual, so only use
    this for regions where ``axis_names`` covers all axes the body touches
    collectively and the specs fully describe the partitioning).

    Closed-over arrays: bodies may close over jax Arrays (decoded tier
    payloads, codec constants). On jax 0.4.x the *eager* experimental
    shard_map refuses operands/closures committed to a single device
    ("incompatible devices for jitted computation") while ``jit(shard_map)``
    happily reshards them onto the mesh — so the fallback is returned
    jit-wrapped. Nested jit is a no-op for callers that already jit.
    """
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=False)
    except (AttributeError, TypeError):
        # Fully-manual fallback: axes outside the specs are replicated. Old
        # shard_map's `auto=` (partial-manual) hits XLA partitioner RET_CHECK
        # failures on gathers, so it is deliberately NOT used here.
        from jax.experimental.shard_map import shard_map as _sm
        return jax.jit(_sm(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False))
