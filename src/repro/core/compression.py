"""Block-wise int8 quantization with error feedback — the paper's LZO analogue.

The paper's observation: on a system whose bottleneck resource also pays for I/O,
*compressing the bytes that transit the bottleneck is a win even when compression costs
compute*. On TPU the slow resource is the interconnect; the TPU-native "LZO" is
block-quantization (cheap VPU math, fixed 2x(+eps) ratio, deterministic).

Error feedback (1-bit-Adam style) keeps the *training trajectory* honest: the
quantization residual is added back into the next step's gradient, so the compression
error is bounded instead of accumulating — `tests/test_compression.py` property-checks
this invariant.

The Pallas kernel in kernels/quantize provides the TPU hot path for `quantize_block`;
this module is the pure-jnp reference implementation used on CPU and in the dry-run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


BLOCK = 256


def axis_size(axis_name) -> int:
    """Static mesh-axis size inside a shard_map body, across jax versions
    (older jax has no ``jax.lax.axis_size``; tuple names multiply)."""
    try:
        return jax.lax.axis_size(axis_name)
    except AttributeError:
        from jax import core
        names = (axis_name if isinstance(axis_name, (tuple, list))
                 else (axis_name,))
        size = 1
        for nm in names:
            frame = core.axis_frame(nm)
            size *= getattr(frame, "size", frame)
        return int(size)


def int8_wire_bytes(n: int, block: int = BLOCK) -> int:
    """Wire bytes for a block-quantized payload of ``n`` scalars: one int8
    code per element plus one fp32 scale per block (zero-padded to a full
    final block). Shared accounting for the gradient codec and the
    ``mapreduce.codecs`` int8 shuffle codec."""
    n_pad = ((max(n, 1) + block - 1) // block) * block
    return n_pad + 4 * (n_pad // block)


def quantize_block(x, block: int = BLOCK):
    """x: [n] (any float dtype) -> (q int8 [n_pad], scales fp32 [n_pad/block], n).

    Per-block symmetric max-abs scaling.
    """
    n = x.shape[-1]
    pad = (-n) % block
    xf = jnp.pad(x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xf.reshape(*x.shape[:-1], -1, block)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(*x.shape[:-1], -1), scale, n


def dequantize_block(q, scale, n: int, dtype=jnp.float32, block: int = BLOCK):
    blocks = q.reshape(*q.shape[:-1], -1, block).astype(jnp.float32)
    x = (blocks * scale[..., None]).reshape(*q.shape[:-1], -1)
    return x[..., :n].astype(dtype)


def compress_roundtrip(x, block: int = BLOCK):
    """dequant(quant(x)) — what the wire sees after one hop."""
    q, s, n = quantize_block(x.reshape(-1), block)
    return dequantize_block(q, s, n, x.dtype, block).reshape(x.shape)


def ef_compress(g, err, block: int = BLOCK):
    """Error-feedback compression step.

    Returns (g_compressed, new_err) with the invariant
        g_compressed + new_err == g + err          (up to fp32 rounding)
    so the residual never leaves the system.
    """
    if err is None:
        err = jnp.zeros_like(g, jnp.float32)
    corrected = g.astype(jnp.float32) + err
    sent = compress_roundtrip(corrected, block)
    new_err = corrected - sent
    return sent.astype(g.dtype), new_err


# ---------------------------------------------------------------------------
# Compressed collectives (bodies for shard_map manual regions)
# ---------------------------------------------------------------------------

def compressed_psum_1d(x, axis_name, block: int = BLOCK):
    """All-reduce of a 1D vector over ``axis_name`` (str or tuple) with int8 payloads.

    Quantized reduce-scatter (a2a of int8 chunks + local fp32 sum) followed by a
    quantized all-gather. Wire bytes ~= n int8 both phases vs 2n bf16 for a ring
    all-reduce (4x reduction + scales overhead).
    """
    R = axis_size(axis_name)
    if R == 1:
        return x
    n = x.shape[0]
    pad = (-n) % (R * block)
    xf = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(R, -1)
    q, s, m = quantize_block(xf)                       # q: [R, m_pad], s: [R, nb]
    q = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0, tiled=False)
    s = jax.lax.all_to_all(s, axis_name, split_axis=0, concat_axis=0, tiled=False)
    chunk = jnp.sum(dequantize_block(q, s, m), axis=0)          # [m] fp32 reduced
    q2, s2, m2 = quantize_block(chunk)
    q2 = jax.lax.all_gather(q2, axis_name, axis=0)
    s2 = jax.lax.all_gather(s2, axis_name, axis=0)
    out = dequantize_block(q2, s2, m2)                          # [R, m]
    return out.reshape(-1)[:n].astype(x.dtype)


def psum_1d(x, axis_name: str):
    return jax.lax.psum(x, axis_name)
