"""Balance report: human-readable rendering of the roofline/Amdahl analysis."""
from __future__ import annotations

from repro.core.amdahl import RooflineTerms


def balance_report(name: str, t: RooflineTerms) -> str:
    d = t.to_dict()
    lines = [
        f"== {name} ==",
        f"  chips={t.chips}  HLO_FLOPs={t.flops:.3e}  HBM_bytes={t.hbm_bytes:.3e}",
        f"  coll_bytes intra={t.coll_bytes_intra:.3e} cross={t.coll_bytes_cross:.3e}",
        f"  t_compute={t.t_compute*1e3:.3f} ms  t_memory={t.t_memory*1e3:.3f} ms  "
        f"t_collective={t.t_collective*1e3:.3f} ms",
        f"  dominant={t.dominant}  step_time(ideal-overlap)={t.step_time*1e3:.3f} ms",
        f"  MODEL_FLOPS={t.model_flops:.3e}  useful_flop_ratio={t.useful_flop_ratio:.3f}",
        f"  roofline_fraction={t.roofline_fraction:.3f}",
        f"  Amdahl: AD={d['AD']:.3f}  ADN={d['ADN']:.3f}  "
        f"chips_to_balance={d['chips_to_balance']:.1f}",
    ]
    return "\n".join(lines)


def suggest(t: RooflineTerms) -> str:
    """One-sentence 'what would move the dominant term down'."""
    dom = t.dominant
    if dom == "compute":
        if t.useful_flop_ratio < 0.5:
            return ("compute-bound with low useful-FLOP ratio: cut recompute/masked "
                    "FLOPs (selective remat, blocked-causal attention)")
        return "compute-bound at high useful ratio: near roofline; scale chips"
    if dom == "memory":
        return ("memory-bound: increase arithmetic intensity (fuse, larger per-chip "
                "batch, avoid re-materialized activations, bf16 everywhere)")
    return ("collective-bound: shrink or re-route wire bytes (hierarchical sync, "
            "int8-compressed collectives, more FSDP/less pure DP)")
