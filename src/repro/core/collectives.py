"""Hierarchical, optionally compressed gradient synchronization.

The paper's transport insight: local (same-node, shared-memory) bytes are cheap; remote
(TCP) bytes are expensive and can only be *reduced*, not accelerated. On a multi-pod
TPU mesh the same split exists between intra-pod ICI and the cross-pod links. The
hierarchical schedule below moves 1/|data| of the bytes across pods:

    flat:          all-reduce over (pod, data)           cross-pod bytes ~ n
    hierarchical:  reduce-scatter over data (intra-pod)
                   -> all-reduce over pod on n/|data|    cross-pod bytes ~ n/16
                   -> all-gather over data (intra-pod)

``codec="int8"`` additionally quantizes the cross-pod phase (the LZO analogue applied
exactly where the paper applied it: on the wire that cannot be made faster).

These functions are shard_map *bodies*: they assume manual axes. ``sync_pytree`` wraps
them over a gradient pytree by flattening to one fp32 vector per dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compression import axis_size, compressed_psum_1d


def flat_psum(x, axes: tuple[str, ...]):
    return jax.lax.psum(x, axes)


def hierarchical_psum_1d(x, inner_axis: str | None, outer_axis: str | None,
                         codec: str = "none"):
    """x: [n] on each device. Returns the (pod,data)-all-reduced vector.

    inner_axis: fast intra-pod axis (reduce-scatter + all-gather)
    outer_axis: slow cross-pod axis (psum on the scattered shard)
    """
    n = x.shape[0]
    if inner_axis is None:
        if outer_axis is None:
            return x
        return (compressed_psum_1d(x, outer_axis) if codec == "int8"
                else jax.lax.psum(x, outer_axis))
    R = axis_size(inner_axis)
    pad = (-n) % R
    xp = jnp.pad(x, (0, pad))
    shard = jax.lax.psum_scatter(xp.reshape(R, -1), inner_axis,
                                 scatter_dimension=0, tiled=False)
    if outer_axis is not None:
        if codec == "int8":
            shard = compressed_psum_1d(shard, outer_axis)
        else:
            shard = jax.lax.psum(shard, outer_axis)
    full = jax.lax.all_gather(shard, inner_axis, axis=0)
    return full.reshape(-1)[:n]
