# The paper's primary contribution: balance-aware execution. Amdahl/roofline
# analysis (amdahl.py, hlo_analysis.py, balance.py) + the three mitigation
# techniques recast for TPU (compression.py = LZO, buckets.py = output
# buffering, collectives.py = shared-memory-vs-TCP locality).
from repro.core.amdahl import (
    RooflineTerms, PEAK_FLOPS, HBM_BW, ICI_BW, ICI_LINKS_PER_CHIP, CROSS_POD_BW,
    model_flops_train, model_flops_prefill, model_flops_decode,
)
from repro.core.balance import balance_report, suggest
from repro.core.buckets import BucketPlan, make_plan, flatten, unflatten
from repro.core.collectives import hierarchical_psum_1d, flat_psum
from repro.core.compression import (
    quantize_block, dequantize_block, compress_roundtrip, ef_compress,
    compressed_psum_1d,
)
from repro.core.hlo_analysis import (
    parse_collectives, collective_summary, op_census,
)
from repro.core.cost_model import (
    BackendProfile, CostModel, StageCost, backend_fingerprint,
    calibration_enabled, get_cost_model, reset_cost_model, stage_census,
)
