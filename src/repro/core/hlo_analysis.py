"""HLO-level analysis: loop-aware FLOPs, HBM-traffic and collective-bytes census.

XLA's ``compiled.cost_analysis()`` visits a ``while`` body once, so any scanned model
(layers under ``lax.scan``) is undercounted by the trip count. We therefore parse the
post-SPMD optimized HLO text ourselves:

- split into computations, build the call graph (while/call/fusion/conditional edges),
- infer each while's trip count from the comparison constant in its condition,
- multiply dot-FLOPs, fusion I/O bytes and collective payloads through the graph.

All quantities are **per device** (the HLO is the SPMD-partitioned single-program
module); multiply by device count for global totals. Collective *wire bytes per chip*
use ring-algorithm factors:

    all-reduce        2 * S * (R-1)/R        (S = operand bytes)
    all-gather        O * (R-1)/R            (O = output bytes)
    reduce-scatter    I * (R-1)/R            (I = operand bytes)
    all-to-all        S * (R-1)/R
    collective-permute  S

Each collective is classified cross-pod if its replica group spans pods.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
# result signature matched lazily: tuples may contain /*index=N*/ comments; the op
# name is the first bare identifier followed by '(' after the '='.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\((.*)$")
_REF_RE = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(.*?)\}[,)\s]")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_bytes(sig: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(sig: str) -> int:
    """Element count of the first shape in a result signature."""
    m = _SHAPE_RE.search(sig)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
    return n


def _dot_flops(result_sig: str, operands: str) -> float:
    """FLOPs of a dot from result shape x contraction size (2*M*N*K).

    K is inferred from the lhs operand shape and the contracting dims annotation.
    Fallback: product(result dims) * 2 * K_guess from operand shapes.
    """
    res = _SHAPE_RE.search(result_sig)
    if not res:
        return 0.0
    out_elems = 1
    if res.group(2):
        for d in res.group(2).split(","):
            if d:
                out_elems *= int(d)
    m = re.search(r"lhs_contracting_dims=\{([\d,]+)\}", operands)
    shapes = _SHAPE_RE.findall(operands)
    if not shapes:
        return 0.0
    lhs_dims = [int(d) for d in shapes[0][1].split(",") if d] if shapes[0][1] else []
    k = 1
    if m and lhs_dims:
        for ci in m.group(1).split(","):
            ci = int(ci)
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
    return 2.0 * out_elems * k


@dataclasses.dataclass
class Collective:
    op: str
    wire_bytes: float            # per chip, algo-factored, loop-multiplied
    payload_bytes: float
    group_size: int
    cross_pod: bool
    mult: float
    line: str


@dataclasses.dataclass
class HLOAnalysis:
    flops: float                 # per device, loop-multiplied (dots only)
    hbm_bytes: float             # per device, fusion/dot/collective I/O
    collectives: list
    coll_wire_intra: float
    coll_wire_cross: float
    coll_count: int
    op_count: int
    while_trips: dict
    # elementwise FLOPs: one per output element of each arithmetic op (plus
    # reduce input elements), counted inside fusions like dot FLOPs. Kept
    # separate from ``flops`` so the dot-only semantics stay stable — the
    # pair kernels are unrolled broadcast sums with zero dots, and the cost
    # model needs their arithmetic visible.
    ew_flops: float = 0.0

    def summary(self) -> dict:
        by_op: dict[str, float] = defaultdict(float)
        for c in self.collectives:
            by_op[c.op] += c.wire_bytes
        return {
            "flops_per_device": self.flops,
            "ew_flops_per_device": self.ew_flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_wire_intra_per_device": self.coll_wire_intra,
            "coll_wire_cross_per_device": self.coll_wire_cross,
            "coll_count": self.coll_count,
            "op_count": self.op_count,
            "coll_by_op": dict(by_op),
        }


def _parse_groups(line: str, pod_size: int) -> tuple[int, bool]:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("}", 1)[0].lstrip("{")
        ids = [int(t) for t in first.split(",") if t.strip().lstrip("-").isdigit()]
        if not ids:
            return 1, False
        cross = len({i // pod_size for i in ids}) > 1 if pod_size else False
        return len(ids), cross
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        reshape = [int(x) for x in m.group(3).split(",")]
        transpose = ([int(x) for x in m.group(4).split(",")]
                     if m.group(4) else list(range(len(reshape))))
        n = int(np.prod(reshape))
        ids = np.arange(n).reshape(reshape).transpose(transpose).reshape(-1)
        first = ids[:gsize]
        cross = (len({int(i) // pod_size for i in first}) > 1
                 if pod_size else False)
        return gsize, cross
    return 1, False


# Ops that do not contribute to the HBM-traffic model. Beyond structural no-ops,
# bare elementwise ops are excluded: the CPU backend leaves many unfused that the TPU
# backend would fuse into neighbors, so counting them would systematically overstate
# TPU HBM traffic. What remains: dot/fusion/reduce/scatter/gather/slice-family/
# concatenate/sort/copy-like data movement + collectives.
_ELEMENTWISE = {
    "add", "multiply", "subtract", "divide", "maximum", "minimum", "select",
    "compare", "convert", "exponential", "exponential-minus-one", "tanh",
    "negate", "rsqrt", "sqrt", "log", "log-plus-one", "power", "and", "or",
    "not", "xor", "clamp", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "logistic", "sine", "cosine",
    "atan2", "rem", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "popcnt", "clz",
}
# Arithmetic elementwise ops counted toward ``ew_flops`` (one per output
# element). ``convert`` is movement, not arithmetic, so it is excluded.
_ARITH_EW = _ELEMENTWISE - {"convert"}

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "call", "conditional", "after-all", "custom-call",
             "copy-start", "copy-done", "partition-id", "replica-id",
             "iota", "broadcast", "reshape", "transpose"} | _ELEMENTWISE


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        ls = line.rstrip()
        if cur is None:
            if ls.endswith("{") and ") -> " in ls:
                tok = ls.split()
                name = tok[1] if tok[0] == "ENTRY" else tok[0]
                cur = name.lstrip("%").split("(")[0]
                comps[cur] = []
            continue
        if ls.strip() == "}":
            cur = None
            continue
        comps[cur].append(line)
    return comps


def _entry_name(hlo_text: str) -> str | None:
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                return m.group(1)
    return None


def _while_trip(cond_lines: list[str]) -> int:
    """Trip count heuristic: max integer constant in the condition computation."""
    best = 1
    for l in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", l):
            best = max(best, int(m.group(1)))
    return best


def analyze_hlo(hlo_text: str, *, pod_size: int = 0) -> HLOAnalysis:
    comps = _split_computations(hlo_text)
    entry = _entry_name(hlo_text)
    if entry is None and comps:
        entry = next(iter(comps))

    # per-computation raw stats + edges
    stats: dict[str, dict] = {}
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    _OPERAND_RE = re.compile(r"%([\w.\-]+)")
    for name, lines in comps.items():
        flops = 0.0
        ew = 0.0
        bytes_ = 0.0
        colls: list[tuple[str, float, int, int, bool, str]] = []
        nops = 0
        # pass 1: symbol table instr name -> (result sig, elem sig of first shape)
        sym: dict[str, str] = {}
        parsed = []
        for line in lines:
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            iname, rsig, op, rest = mi.groups()
            sym[iname] = rsig
            parsed.append((iname, rsig, op, rest, line))

        def operand_sigs(rest: str) -> list[str]:
            head = rest.split("), ")[0]
            return [sym.get(n, "") for n in _OPERAND_RE.findall(head)]

        # pass 2
        for iname, rsig, op, rest, line in parsed:
            nops += 1
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                trip = _while_trip(comps.get(mc.group(1), [])) if mc else 1
                if mb:
                    edges[name].append((mb.group(1), float(max(trip, 1)), True))
                if mc:
                    edges[name].append((mc.group(1), float(max(trip, 1)), True))
                continue
            mbr = _BRANCH_RE.search(line)
            if mbr:
                for b in mbr.group(1).split(","):
                    edges[name].append((b.strip().lstrip("%"), 1.0, True))
            if op == "fusion":
                # fusion internals: count FLOPs (dots) but not HBM bytes
                for r in _REF_RE.finditer(line):
                    edges[name].append((r.group(1), 1.0, False))
            else:
                for r in _REF_RE.finditer(line):
                    edges[name].append((r.group(1), 1.0, True))
            opnds = operand_sigs(rest)
            in_bytes = sum(_shape_bytes(s) for s in opnds)
            out_bytes = _shape_bytes(rsig)
            if op in _ARITH_EW:
                ew += _shape_elems(rsig)
            elif op in ("reduce", "reduce-window"):
                ew += sum(_shape_elems(s) for s in opnds)
            if op == "dot":
                flops += _dot_flops(rsig, " ".join(opnds) + " " + rest)
                bytes_ += in_bytes + out_bytes
            elif op in COLLECTIVE_OPS or any(op == c + "-start"
                                             for c in COLLECTIVE_OPS):
                base = op.replace("-start", "")
                payload_in = in_bytes
                payload_out = out_bytes
                gsize, cross = _parse_groups(line, pod_size)
                R = max(gsize, 1)
                factor = (R - 1) / R
                if base == "all-reduce":
                    wire = 2.0 * payload_in * factor
                elif base == "all-gather":
                    wire = payload_out * factor
                elif base == "reduce-scatter":
                    wire = payload_in * factor
                elif base == "all-to-all":
                    wire = payload_in * factor
                else:                      # collective-permute
                    wire = payload_in
                colls.append((base, wire, max(payload_in, payload_out), R, cross,
                              line.strip()[:160]))
                bytes_ += payload_in + payload_out
            elif op in ("dynamic-update-slice",):
                # in-place update: only the slice is read+written. The update is
                # the second-largest operand (largest = aliased buffer; the rest
                # are scalar indices) — robust to fusion-parameter orderings.
                ob = sorted((_shape_bytes(s) for s in opnds), reverse=True)
                upd = ob[1] if len(ob) > 1 else (ob[0] if ob else 0)
                bytes_ += 2 * upd
            elif op in ("dynamic-slice", "gather", "slice"):
                # only the extracted slice moves
                bytes_ += 2 * out_bytes
            elif op == "copy":
                # loop-carry copies are elided by buffer aliasing on TPU
                pass
            elif op == "fusion" and "dynamic-update-slice" in iname:
                # fusion ending in an in-place DUS: the big aliased buffer is
                # untouched except for the written slice ~= other operands
                ops_b = [_shape_bytes(s) for s in opnds]
                big = max(ops_b) if ops_b else 0
                bytes_ += 2 * max(sum(ops_b) - big, 0)
            elif op == "fusion" or op not in _SKIP_OPS:
                # HBM traffic model: operands + result cross HBM per fusion/op
                bytes_ += in_bytes + out_bytes
        stats[name] = {"flops": flops, "ew": ew, "bytes": bytes_,
                       "colls": colls, "nops": nops}

    # propagate multipliers from entry: (flops multiplier, bytes multiplier)
    multf: dict[str, float] = defaultdict(float)
    multb: dict[str, float] = defaultdict(float)

    def visit(name: str, mf: float, mb: float, depth=0):
        if name not in comps or depth > 64:
            return
        multf[name] += mf
        multb[name] += mb
        for child, k, count_bytes in edges.get(name, []):
            visit(child, mf * k, mb * k if count_bytes else 0.0, depth + 1)

    if entry:
        visit(entry, 1.0, 1.0)

    total_flops = 0.0
    total_ew = 0.0
    total_bytes = 0.0
    coll_list: list[Collective] = []
    wire_intra = wire_cross = 0.0
    ncoll = 0
    nops = 0
    trips = {}
    for name, st in stats.items():
        mf = multf.get(name, 0.0)
        mb = multb.get(name, 0.0)
        if mf <= 0 and mb <= 0:
            continue
        total_flops += st["flops"] * mf
        total_ew += st["ew"] * mf
        total_bytes += st["bytes"] * mb
        nops += int(st["nops"] * mb)
        for (op, wire, payload, R, cross, line) in st["colls"]:
            m = mb
            if m <= 0:
                continue
            coll_list.append(Collective(op, wire * m, payload, R, cross, m, line))
            ncoll += int(m)
            if cross:
                wire_cross += wire * m
            else:
                wire_intra += wire * m
    return HLOAnalysis(total_flops, total_bytes, coll_list, wire_intra,
                       wire_cross, ncoll, nops, trips, ew_flops=total_ew)


# Back-compat helpers -------------------------------------------------------

def parse_collectives(hlo_text: str, *, pod_size: int = 0):
    return analyze_hlo(hlo_text, pod_size=pod_size).collectives


def collective_summary(hlo_text: str, *, pod_size: int = 0) -> dict:
    a = analyze_hlo(hlo_text, pod_size=pod_size)
    s = a.summary()
    return {
        "count": a.coll_count,
        "bytes_total": a.coll_wire_intra + a.coll_wire_cross,
        "bytes_intra_pod": a.coll_wire_intra,
        "bytes_cross_pod": a.coll_wire_cross,
        "by_op": s["coll_by_op"],
    }


def op_census(hlo_text: str) -> dict[str, int]:
    census: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            census[m.group(3)] += 1
    return dict(census)
