"""Logical-axis sharding rules with divisibility fallbacks.

Params and activations are annotated with *logical* dimension names; rules map those to
mesh axes. A rule only applies when the dimension size divides evenly by the product of
the mapped mesh-axis sizes — otherwise the dimension is left unsharded (this is how
archs with e.g. 8 heads survive a 16-way model axis: attention falls back to sequence
sharding, see models/attention.py).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Param schema
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical dims + init."""
    shape: tuple[int, ...]
    dims: tuple[Any, ...]            # logical names (str) or None, len == rank
    init: str = "normal"             # normal | zeros | ones
    scale: float = -1.0              # -1 -> 1/sqrt(fan_in) (fan_in = shape[dims.index-ish 0])
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)


def is_paramdef(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_schema(fn, schema):
    """Map over a nested dict schema whose leaves are ParamDefs, keeping paths."""
    def rec(node, path):
        if is_paramdef(node):
            return fn(path, node)
        if isinstance(node, dict):
            return {k: rec(v, path + (k,)) for k, v in node.items()}
        raise TypeError(f"bad schema node at {path}: {type(node)}")
    return rec(schema, ())


def init_params(schema, key, dtype_override: str | None = None):
    """Materialize a schema into arrays (deterministic per path)."""
    def make(path, pd: ParamDef):
        dt = jnp.dtype(dtype_override or pd.dtype)
        if pd.init == "zeros":
            return jnp.zeros(pd.shape, dt)
        if pd.init == "ones":
            return jnp.ones(pd.shape, dt)
        k = jax.random.fold_in(key, hash("/".join(map(str, path))) % (2**31))
        scale = pd.scale
        if scale < 0:
            fan_in = pd.shape[0] if len(pd.shape) >= 1 else 1
            for s, d in zip(pd.shape, pd.dims):
                if d == "embed":            # prefer the model-dim as fan-in when marked
                    fan_in = s
                    break
            scale = 1.0 / float(np.sqrt(max(fan_in, 1)))
        return (jax.random.normal(k, pd.shape, jnp.float32) * scale).astype(dt)
    return tree_map_schema(make, schema)


def abstract_params(schema):
    """ShapeDtypeStructs for a schema (no allocation — dry-run path)."""
    return tree_map_schema(
        lambda path, pd: jax.ShapeDtypeStruct(pd.shape, jnp.dtype(pd.dtype)), schema)


def count_params(schema, active_fraction_for: dict[str, float] | None = None) -> int:
    total = 0
    def add(path, pd: ParamDef):
        nonlocal total
        n = int(np.prod(pd.shape))
        if active_fraction_for:
            for marker, frac in active_fraction_for.items():
                if any(marker in str(p) for p in path):
                    n = int(n * frac)
                    break
        total += n
        return None
    tree_map_schema(add, schema)
    return total


# ---------------------------------------------------------------------------
# Axis rules
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AxisRules:
    """Logical axis -> tuple of mesh axis names."""
    rules: dict[str, tuple[str, ...]]

    def axes_for(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return self.rules.get(logical, ())


def make_rules(mesh: Mesh, *, pod_param_mode: str = "sharded") -> AxisRules:
    """pod_param_mode: 'sharded' (FSDP over pod+data), 'data' (FSDP within pod,
    replicated across pods), 'replicated' (pure DP: params replicated over pod+data,
    TP over model only — the paper-faithful Hadoop-style baseline)."""
    names = set(mesh.axis_names)
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    if pod_param_mode == "sharded":
        fsdp_axes = batch_axes
    elif pod_param_mode == "data":
        fsdp_axes = tuple(a for a in ("data",) if a in names)
    elif pod_param_mode == "replicated":
        fsdp_axes = ()
    else:
        raise ValueError(pod_param_mode)
    model = ("model",) if "model" in names else ()
    return AxisRules(rules={
        "batch": batch_axes,
        "embed": fsdp_axes,        # FSDP dim on weights
        "vocab": model,
        "mlp": model,
        "heads": model,
        "kv_heads": model,
        "head_dim": model,         # fallback for KV caches with few heads
        "experts": model,
        "expert_ff": fsdp_axes,    # expert hidden dim: FSDP (gathered in MoE body)
        "state": model,            # SSM d_inner channels
        "seq_model": model,        # sequence-parallel attention fallback
        "seq": (),
        "layers": (),
    })


# Thread-local mesh/rules context so model code can be mesh-agnostic.
class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: AxisRules | None = None
    manual: frozenset = frozenset()

_CTX = _Ctx()


def _filter_rules(rules: AxisRules, manual: frozenset) -> AxisRules:
    """Drop manual axes from every rule (they are invalid in auto constraints)."""
    if not manual:
        return rules
    return AxisRules(rules={k: tuple(a for a in v if a not in manual)
                            for k, v in rules.rules.items()})


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: AxisRules | None = None,
             manual_axes: frozenset = frozenset()):
    old = (_CTX.mesh, _CTX.rules, _CTX.manual)
    _CTX.mesh = mesh
    base = rules or (make_rules(mesh) if mesh is not None else None)
    _CTX.rules = _filter_rules(base, manual_axes) if base else None
    _CTX.manual = manual_axes
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules, _CTX.manual = old


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def current_rules() -> AxisRules | None:
    return _CTX.rules


def current_manual_axes() -> frozenset:
    return _CTX.manual


def sharding_mesh():
    """Mesh object to build NamedShardings / nested shard_maps from.

    Inside a partially-manual shard_map region, sharding objects must reference the
    ambient AbstractMesh (whose axis types mark the manual axes); at top level, the
    concrete mesh.
    """
    if _CTX.manual:
        get_am = getattr(jax.sharding, "get_abstract_mesh", None)
        am = get_am() if get_am is not None else None   # older jax: no ambient
        if am is not None and am.axis_names:
            return am
    return _CTX.mesh


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def _axes_fit(size: int, axes: tuple[str, ...], mesh: Mesh) -> bool:
    prod = 1
    for a in axes:
        if a not in mesh.axis_names:
            return False
        prod *= mesh.shape[a]
    return prod > 0 and size % prod == 0


def spec_for(shape: tuple[int, ...], dims: tuple[Any, ...],
             mesh: Mesh | None = None, rules: AxisRules | None = None) -> P:
    """PartitionSpec for a shape with logical dims, dropping non-dividing axes."""
    mesh = mesh or current_mesh()
    rules = rules or current_rules()
    if mesh is None or rules is None:
        return P()
    used: set[str] = set()
    parts = []
    for size, logical in zip(shape, dims):
        axes = rules.axes_for(logical)
        axes = tuple(a for a in axes if a not in used)
        if axes and _axes_fit(size, axes, mesh):
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard_act(x: jax.Array, dims: tuple[Any, ...]) -> jax.Array:
    """with_sharding_constraint by logical dims (no-op outside a mesh context)."""
    mesh = current_mesh()
    rules = current_rules()
    if mesh is None or rules is None:
        return x
    spec = spec_for(x.shape, dims, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(sharding_mesh(), spec))


def sharding_tree(schema, mesh: Mesh, rules: AxisRules):
    """NamedSharding tree matching a schema."""
    return tree_map_schema(
        lambda path, pd: NamedSharding(mesh, spec_for(pd.shape, pd.dims, mesh, rules)),
        schema)


def batch_axes(mesh: Mesh | None = None) -> tuple[str, ...]:
    """Batch axes not already captured by an enclosing manual region."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data")
                 if a in mesh.axis_names and a not in _CTX.manual)


def batch_spec(rank: int, mesh: Mesh | None = None) -> P:
    """P over batch axes on dim0, rest unsharded."""
    ba = batch_axes(mesh)
    if not ba:
        return P()
    return P(ba if len(ba) > 1 else ba[0], *([None] * (rank - 1)))
