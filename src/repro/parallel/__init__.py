from repro.parallel.sharding import (
    ParamDef, AxisRules, make_rules, use_mesh, current_mesh, current_rules,
    spec_for, shard_act, sharding_tree, init_params, abstract_params,
    tree_map_schema, count_params, batch_axes, batch_spec, axis_size,
)
