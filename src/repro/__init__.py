"""repro: balance-aware JAX/TPU training+serving framework reproducing
"Hadoop in Low-Power Processors" (Zheng, Szalay, Terzis; 2014) — see DESIGN.md."""

__version__ = "0.1.0"
