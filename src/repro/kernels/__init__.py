# Pallas TPU kernels for the perf-critical hot spots the paper's workloads expose:
#   quantize/         block int8 quantize/dequant (compressed collectives' hot path)
#   zones_pairs/      blockwise pair search (the astronomy apps' reducer hot spot)
#   flash_attention/  causal GQA flash fwd (removes score-matrix HBM traffic)
# Each has kernel.py (pl.pallas_call + BlockSpec VMEM tiling), ops.py (jit'd
# wrapper with backend dispatch), ref.py (pure-jnp oracle for allclose sweeps).
