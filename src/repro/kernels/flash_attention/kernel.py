"""Pallas TPU kernel: causal GQA flash attention (forward).

Canonical Mosaic pattern: grid (B, H, nq, nk) with the KV index innermost; VMEM
scratch (m, l, acc) persists across the sequential nk iterations and is reset at
nk == 0 via ``pl.when``. Causal + sliding-window blocks that cannot contribute are
skipped (no MXU work issued). GQA is expressed in the BlockSpec index maps
(q head h reads kv head h // group).

Score tiles [bq, bk] never leave VMEM — on TPU this removes the score-matrix HBM
traffic that dominates the chunked pure-XLA fallback's memory term (see §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e9


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, nk: int, seq: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_lo = iq * bq
    k_lo = ik * bk
    # static-shape block skip test (trace-time values are dynamic; use lax.cond
    # semantics via pl.when)
    need = jnp.bool_(True)
    if causal:
        need = need & (k_lo <= q_lo + bq - 1)
    if window:
        need = need & (k_lo + bk - 1 >= q_lo - (window - 1))

    @pl.when(need)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # [bq, dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos < seq
        if causal:
            ok &= qpos >= kpos
        if window:
            ok &= qpos - kpos < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_sc[...]                                  # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=-1)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * alpha[:, None] + p @ v

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_sc[...], 1e-20)
        o_ref[0, :, 0, :] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, scale: float | None = None,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = False):
    """q: [B,S,H,dh], k/v: [B,S,Kv,dh]. Forward only."""
    B, S, H, dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    if scale is None:
        scale = 1.0 / math.sqrt(dh)
    bq = min(bq, S)
    bk = min(bk, S)
    padq = (-S) % bq
    padk = (-S) % bk
    if padq or padk:
        q = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, padk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padk), (0, 0), (0, 0)))
    Sq, Sk = q.shape[1], k.shape[1]
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk, seq=S)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b, h, i, j, g=G: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b, h, i, j, g=G: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dh), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, dh), q.dtype),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),        # running max
            _vmem((bq,), jnp.float32),        # running denominator
            _vmem((bq, dh), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
