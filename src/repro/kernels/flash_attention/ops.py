"""Jit'd wrapper: Pallas flash (TPU) or interpret-mode / chunked jnp (CPU).

Training uses a custom_vjp: Pallas forward + reference backward (XLA-differentiated
recompute) — forward inference/serving is where the kernel matters most.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# nondiff args by position (3..7): works on jax versions without
# custom_vjp(nondiff_argnames=...)
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=0, softcap=0.0, scale=None,
                    use_pallas=None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      softcap=softcap, scale=scale,
                                      interpret=not _on_tpu())
    return attention_ref(q, k, v, causal=causal, window=window,
                         softcap=softcap, scale=scale)


def _fwd(q, k, v, causal, window, softcap, scale, use_pallas):
    o = flash_attention(q, k, v, causal, window, softcap, scale, use_pallas)
    return o, (q, k, v)


def _bwd(causal, window, softcap, scale, use_pallas, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window, softcap=softcap,
                                         scale=scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
