"""Pure-jnp oracle for the flash attention kernel (causal GQA, softcap, window)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: float | None = None):
    """q: [B,S,H,dh], k/v: [B,S,Kv,dh] -> [B,S,H,dh]."""
    B, S, H, dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    if scale is None:
        scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, S, Kv, G, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)
    rel = pos[:, None] - pos[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= rel >= 0
    if window:
        ok &= rel < window
    s = jnp.where(ok[None, None, None], s, -2e9)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, S, H, dh)
