from repro.kernels.quantize.ops import quantize, dequantize
