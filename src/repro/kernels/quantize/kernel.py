"""Pallas TPU kernel: block-wise int8 quantize / dequantize.

The hot path of compressed collectives (core/compression.py): one VPU pass computing
per-256-element max-abs scales and the rounded int8 payload. Tiled so each grid step
owns a [TR, C] row-stripe resident in VMEM (C = lane-aligned multiple of 256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256
TILE_ROWS = 8


def _quant_kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)             # [TR, C]
    tr, c = x.shape
    nb = c // block
    xb = x.reshape(tr, nb, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    q_ref[...] = q.reshape(tr, c).astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref, *, block: int, dtype):
    q = q_ref[...].astype(jnp.float32)
    tr, c = q.shape
    nb = c // block
    x = q.reshape(tr, nb, block) * s_ref[...][..., None]
    o_ref[...] = x.reshape(tr, c).astype(dtype)


def quantize_pallas(x, *, block: int = BLOCK, tile_rows: int = TILE_ROWS,
                    interpret: bool = False):
    """x: [R, C] float, R % tile_rows == 0, C % block == 0."""
    R, C = x.shape
    nb = C // block
    grid = (R // tile_rows,)
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, block=block),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_rows, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile_rows, C), lambda i: (i, 0)),
                   pl.BlockSpec((tile_rows, nb), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R, nb), jnp.float32)],
        interpret=interpret,
    )(x)
    return q, s


def dequantize_pallas(q, s, *, dtype=jnp.float32, block: int = BLOCK,
                      tile_rows: int = TILE_ROWS, interpret: bool = False):
    R, C = q.shape
    nb = C // block
    grid = (R // tile_rows,)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, block=block, dtype=dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_rows, C), lambda i: (i, 0)),
                  pl.BlockSpec((tile_rows, nb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), dtype),
        interpret=interpret,
    )(q, s)
