"""Pure-jnp oracle for block int8 quantization (matches core/compression.py)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x, block: int = 256):
    """x: [R, C] float (C % block == 0) -> (q int8 [R, C], scales f32 [R, C/block])."""
    R, C = x.shape
    nb = C // block
    xb = x.astype(jnp.float32).reshape(R, nb, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(R, C), scale


def dequantize_ref(q, scale, dtype=jnp.float32, block: int = 256):
    R, C = q.shape
    nb = C // block
    xb = q.reshape(R, nb, block).astype(jnp.float32) * scale[..., None]
    return xb.reshape(R, C).astype(dtype)
