"""Jit'd public wrapper: Pallas on TPU, interpret-mode Pallas or jnp ref on CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import quantize_pallas, dequantize_pallas
from repro.kernels.quantize.ref import quantize_ref, dequantize_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block", "use_pallas"))
def quantize(x, *, block: int = 256, use_pallas: bool | None = None):
    """x: [R, C] -> (q int8 [R,C], scales f32 [R, C//block])."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return quantize_pallas(x, block=block, interpret=not _on_tpu())
    return quantize_ref(x, block=block)


@functools.partial(jax.jit, static_argnames=("block", "use_pallas"))
def dequantize(q, s, *, block: int = 256, use_pallas: bool | None = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return dequantize_pallas(q, s, block=block, interpret=not _on_tpu())
    return dequantize_ref(q, s, block=block)
