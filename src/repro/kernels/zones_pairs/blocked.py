"""Z-banded blocked reduce: the CPU/XLA twin of the masked Pallas grid.

The masked-batched kernels cover every (owned-tile, bucket-tile) pair of a
partition. For the Zones algorithm that is wasteful: a within-radius pair
satisfies ``|z_i - z_j| <= |v_i - v_j| <= sqrt(2*max_norm^2 - 2*cos_min)``,
so tile pairs whose z-ranges are further apart than that bound *cannot*
contain a hit and can be skipped outright. This module:

1. chops every partition of a [P, C, 3] tier into fixed TM/TN-row tiles and
   computes per-tile z ranges on device (padding rows masked out),
2. prunes tile pairs with the (conservative, codec-error-aware) z-gap bound
   on the host — index metadata only, a [P, gm, gn] boolean,
3. gathers the surviving tile pairs into a block stream and reduces it in
   fixed-shape chunks ([B0, TM, 3] x [B0, TN, 3]) through ONE jitted masked
   kernel, so the expensive XLA compile happens once per process instead of
   once per job shape.

The pruning bound is exact: a skipped tile pair provably contains no dot
``>= cos_min`` even after f32 rounding (the slack term covers it), so
blocked results match the dense masked reference bit-for-bit — this is
property-checked in ``tests/test_kernels.py``.

Chunk geometry: TM=TN=64 rows (falls back to the largest divisor of the
capacity), B0=512 blocks per chunk — ~2M score cells per dispatch, enough
to amortize dispatch overhead while keeping the [B0, TM, TN] score tensor
inside the L2-ish working set. ``chunk_shape()`` resolves the shape per
run: ``set_chunk_shape`` override > ``REPRO_AUTO_CHUNK=1`` (the cost
model's calibrated replay-measured choice) > these constants. Results are
bit-identical for every shape.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.zones_pairs.kernel import _fit_tile
from repro.kernels.zones_pairs.ref import _batched_dots, _pair_mask

TM = 64           # tile rows (owned side)
TN = 64           # tile rows (bucket side)
B0 = 512          # blocks per kernel dispatch (fixed -> one compile)
_SLACK = 1e-3     # covers f32 rounding in dots/ranges/threshold

# chunk-shape resolution: hand-tuned module constants by default; an explicit
# override (tests / power users) wins; REPRO_AUTO_CHUNK=1 asks the cost
# model, which only deviates from the hand-tuned shape when its calibration
# replay measured a faster one. Any shape is exact — tiles are masked and
# ``_fit_tile`` handles every capacity — so this changes speed, never bits.
_CHUNK_OVERRIDE: "tuple[int, int, int] | None" = None


def set_chunk_shape(tm: int | None = None, tn: int | None = None,
                    b0: int | None = None) -> None:
    """Force a (TM, TN, B0) chunk shape; ``set_chunk_shape()`` resets to
    the default resolution order."""
    global _CHUNK_OVERRIDE
    _CHUNK_OVERRIDE = (None if tm is None
                       else (int(tm), int(tn or tm), int(b0 or B0)))


def chunk_shape() -> "tuple[int, int, int]":
    """The (TM, TN, B0) the blocked engine will use for the next run."""
    if _CHUNK_OVERRIDE is not None:
        return _CHUNK_OVERRIDE
    if os.environ.get("REPRO_AUTO_CHUNK") == "1":
        from repro.core.cost_model import get_cost_model
        return get_cost_model().choose_blocked_chunk(default=(TM, TN, B0))
    return (TM, TN, B0)


@jax.jit
def _count_chunk(a, b, na, nb, cos_min):
    """[B0,TM,3], [B0,TN,3], [B0], [B0] -> masked pair count (int32).
    Shares ``ref._batched_dots``/``ref._pair_mask`` so the scores are
    bit-identical to every other engine path (the parity contract)."""
    dots = _batched_dots(a, b)
    ok = (dots >= cos_min) & _pair_mask(a.shape[1], b.shape[1], na, nb)
    return jnp.sum(ok, dtype=jnp.int32)


@jax.jit
def _hist_chunk(a, b, na, nb, cos_edges):
    """Cumulative per-edge counts for one chunk (edges descending in cos).
    ``fori_loop`` over edges so the score tensor is hoisted out of the loop
    and materialized ONCE: a broadcast ``dots >= edges[:, None]`` fuses the
    dot computation into every edge row (NB-fold recompute, ~10x slower),
    and searchsorted lowers to a per-element binary-search gather on CPU
    (worse still)."""
    dots = jnp.where(_pair_mask(a.shape[1], b.shape[1], na, nb),
                     _batched_dots(a, b), -2.0)

    def body(k, acc):
        return acc.at[k].set(jnp.sum(dots >= cos_edges[k], dtype=jnp.int32))

    return jax.lax.fori_loop(0, cos_edges.shape[0], body,
                             jnp.zeros(cos_edges.shape, jnp.int32))


@functools.partial(jax.jit, static_argnames=("gm", "tm"))
def _tile_ranges(x, n_rows, *, gm, tm):
    """Per-tile z min/max + max squared norm, padding rows masked.
    x: [P, C, 3], n_rows: [P] -> (zmin [P,gm], zmax [P,gm], max_norm2)."""
    P = x.shape[0]
    z = x[..., 2].reshape(P, gm, tm)
    n2 = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1).reshape(P, gm, tm)
    row = jnp.arange(gm * tm, dtype=jnp.int32).reshape(gm, tm)
    valid = row[None] < n_rows[:, None, None]
    zmin = jnp.min(jnp.where(valid, z, jnp.inf), axis=-1)
    zmax = jnp.max(jnp.where(valid, z, -jnp.inf), axis=-1)
    mn2 = jnp.max(jnp.where(valid, n2, 0.0))
    return zmin, zmax, mn2


def _plan_blocks(a, b, n_a, n_b, cos_min, tm0=None, tn0=None):
    """-> (a_tile_idx, b_tile_idx, na_blk, nb_blk) numpy arrays of surviving
    tile pairs, plus (gm, tm, gn, tn). Empty tiles and z-gap-pruned tile
    pairs are dropped."""
    P, C1, _ = a.shape
    C2 = b.shape[1]
    tm = _fit_tile(C1, TM if tm0 is None else tm0)
    tn = _fit_tile(C2, TN if tn0 is None else tn0)
    gm, gn = C1 // tm, C2 // tn
    azmin, azmax, amn2, bzmin, bzmax, bmn2 = jax.device_get(
        _tile_ranges(a, n_a, gm=gm, tm=tm)
        + _tile_ranges(b, n_b, gm=gn, tm=tn))    # one host sync
    mn2 = float(max(amn2, bmn2))
    # |z_i - z_j| > sqrt(|v_i|^2 + |v_j|^2 - 2*cos_min)  =>  dot < cos_min
    thresh = float(np.sqrt(max(2.0 * mn2 - 2.0 * float(cos_min), 0.0))
                   ) + _SLACK
    gap = np.maximum(bzmin[:, None, :] - azmax[:, :, None],
                     azmin[:, :, None] - bzmax[:, None, :])   # [P, gm, gn]
    pi, ii, jj = np.nonzero(gap <= thresh)    # empty tiles: gap == +inf
    na_blk = np.clip(np.asarray(n_a)[pi] - ii * tm, 0, tm).astype(np.int32)
    nb_blk = np.clip(np.asarray(n_b)[pi] - jj * tn, 0, tn).astype(np.int32)
    return ((pi * gm + ii).astype(np.int32), (pi * gn + jj).astype(np.int32),
            na_blk, nb_blk, (gm, tm, gn, tn))


@jax.jit
def _pick_chunk(A, B, na, nb, k):
    """One dispatch for all four chunk slices (cheap slicing-only compile)."""
    f = lambda x: jax.lax.dynamic_index_in_dim(x, k, 0, keepdims=False)
    return f(A), f(B), f(na), f(nb)


def _gather_blocks(x, idx, g, t):
    flat = x.reshape((x.shape[0] * g, t) + x.shape[2:])
    if jax.default_backend() == "cpu":
        # numpy fancy indexing (zero-copy view in) beats XLA's eager gather
        # by ~5x on CPU; on accelerators keep the data device-resident
        return jnp.asarray(np.asarray(flat)[idx])
    return flat[jnp.asarray(idx)]


def _run_blocked(a, b, n_a, n_b, cos_min, chunk_fn, chunk_arg, out0):
    tm0, tn0, b0 = chunk_shape()
    ai, bi, na_blk, nb_blk, (gm, tm, gn, tn) = _plan_blocks(
        a, b, n_a, n_b, cos_min, tm0, tn0)
    nblk = len(ai)
    if not nblk:              # everything pruned or empty
        return out0
    pad = (-nblk) % b0
    if pad:   # padded blocks point at tile 0 with zero-row masks
        z = np.zeros(pad, np.int32)
        ai, bi = np.concatenate([ai, z]), np.concatenate([bi, z])
        na_blk, nb_blk = (np.concatenate([na_blk, z]),
                          np.concatenate([nb_blk, z]))
    nchunks = (nblk + pad) // b0
    A = _gather_blocks(a, ai, gm, tm).reshape(nchunks, b0, tm, -1)
    B = _gather_blocks(b, bi, gn, tn).reshape(nchunks, b0, tn, -1)
    na_d = jnp.asarray(na_blk).reshape(nchunks, b0)
    nb_d = jnp.asarray(nb_blk).reshape(nchunks, b0)
    out = out0
    for k in range(nchunks):   # dynamic index: one compiled slice per shape
        out = out + chunk_fn(*_pick_chunk(A, B, na_d, nb_d, jnp.int32(k)),
                             chunk_arg)
    return out


def pair_count_blocked(a, b, n_a, n_b, cos_min):
    """Z-banded blocked twin of ``pair_count_masked_ref`` ([P,C1,3] x
    [P,C2,3] + real counts -> total int32). Exact same result; skips tile
    pairs that provably cannot contain a within-threshold pair."""
    if a.shape[-1] != 3:   # pruning bound assumes 3D unit-ish vectors
        from repro.kernels.zones_pairs.ref import pair_count_masked_ref
        return pair_count_masked_ref(a, b, n_a, n_b, cos_min)
    return _run_blocked(a, b, n_a, n_b, cos_min, _count_chunk,
                        jnp.float32(cos_min), jnp.int32(0))


def pair_hist_blocked(a, b, n_a, n_b, cos_edges):
    """Z-banded blocked twin of ``pair_hist_masked_ref`` (cumulative counts
    per cos edge, edges descending in cos). Pruning uses the loosest edge."""
    if a.shape[-1] != 3:
        from repro.kernels.zones_pairs.ref import pair_hist_masked_ref
        return pair_hist_masked_ref(a, b, n_a, n_b, cos_edges)
    edges = jnp.asarray(cos_edges, jnp.float32)
    cos_min = float(jnp.min(edges))
    return _run_blocked(a, b, n_a, n_b, cos_min, _hist_chunk, edges,
                        jnp.zeros(edges.shape, jnp.int32))
