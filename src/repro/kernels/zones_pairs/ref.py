"""Pure-jnp oracle for the Zones pair kernel (plain and masked-batched)."""
from __future__ import annotations

import jax.numpy as jnp


def _dots2d(a, b):
    """[M,d] x [N,d] -> [M,N] scores as an unrolled broadcast sum. Every
    engine path (host lax.map, masked-batched, z-banded blocked) shares this
    formulation so scores agree bit-for-bit: XLA lowers a d=3 dot_general
    with FMA (no intermediate rounding), which differs in the last ulp from
    the rounded product sum and would flip pairs sitting exactly on a
    threshold."""
    a, b = a.astype(jnp.float32), b.astype(jnp.float32)
    return sum(a[:, None, k] * b[None, :, k] for k in range(a.shape[-1]))


def pair_count_ref(a, b, cos_min, *, exclude_self: bool = False):
    """a: [M,3], b: [N,3] unit vectors. Count of (i,j) with a_i . b_j >= cos_min.

    exclude_self: drop the diagonal (use when a and b are the same block).
    """
    ok = _dots2d(a, b) >= cos_min
    if exclude_self:
        M, N = ok.shape
        ok = ok & ~jnp.eye(M, N, dtype=bool)
    return jnp.sum(ok, dtype=jnp.int32)


def pair_hist_ref(a, b, cos_edges, *, exclude_self: bool = False):
    """Cumulative counts per edge: out[k] = #{(i,j): dot >= cos_edges[k]}.

    cos_edges descending in angle (i.e. cos ascending? NO: theta_k ascending =>
    cos_edges descending). The differential histogram for bin (theta_{k-1},theta_k]
    is out[k] - out[k-1].
    """
    dots = _dots2d(a, b)
    if exclude_self:
        M, N = dots.shape
        dots = jnp.where(jnp.eye(M, N, dtype=bool), -2.0, dots)
    return jnp.sum(dots[None, :, :] >= cos_edges[:, None, None],
                   axis=(1, 2), dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Masked-batched variants: leading partition axis + per-partition real counts
# (the engine="device" reduce — padded rows are *masked out*, not neutralized
# by pad-value tricks, so one skewed partition can't poison the others).
# ---------------------------------------------------------------------------

def _pair_mask(M, N, n_a, n_b):
    """[P, M, N] validity: row i of partition p is real iff i < n_a[p]."""
    mi = jnp.arange(M, dtype=jnp.int32)[None, :] < n_a[:, None]    # [P, M]
    mj = jnp.arange(N, dtype=jnp.int32)[None, :] < n_b[:, None]    # [P, N]
    return mi[:, :, None] & mj[:, None, :]


def _batched_dots(a, b):
    """[P,M,d] x [P,N,d] -> [P,M,N] dot scores; same unrolled broadcast
    formulation as ``_dots2d`` (bit-identical scores across engine paths; on
    CPU also ~5x faster to run and ~2x faster to compile than a d=3
    dot_general)."""
    a, b = a.astype(jnp.float32), b.astype(jnp.float32)
    return sum(a[:, :, None, k] * b[:, None, :, k]
               for k in range(a.shape[-1]))


def pair_count_masked_ref(a, b, n_a, n_b, cos_min):
    """a: [P,M,3], b: [P,N,3], n_a/n_b: [P] real counts. Total count of
    valid (p,i,j) with a[p,i] . b[p,j] >= cos_min, summed over partitions."""
    dots = _batched_dots(a, b)
    ok = (dots >= cos_min) & _pair_mask(a.shape[1], b.shape[1], n_a, n_b)
    return jnp.sum(ok, dtype=jnp.int32)


def pair_hist_masked_ref(a, b, n_a, n_b, cos_edges):
    """Cumulative counts per edge over all partitions: out[k] = #{valid
    (p,i,j): dot >= cos_edges[k]} (edges descending in cos == ascending in
    angle, as in ``pair_hist_ref``).

    One binning pass (searchsorted + bincount) instead of an NB-fold
    broadcast, so the [P, M, N] score tensor is read once regardless of the
    number of edges."""
    dots = jnp.where(_pair_mask(a.shape[1], b.shape[1], n_a, n_b),
                     _batched_dots(a, b), -2.0)
    asc = cos_edges[::-1]                                  # ascending cos
    nb = asc.shape[0]
    # c = #edges <= dot; then #dots >= asc[j] == #dots with c > j
    c = jnp.searchsorted(asc, dots.ravel(), side="right")
    h = jnp.bincount(c, length=nb + 1)
    cum_from_top = jnp.cumsum(h[::-1])[::-1]               # [nb+1]
    return cum_from_top[1:][::-1].astype(jnp.int32)        # reorder to edges
