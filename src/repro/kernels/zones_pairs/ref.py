"""Pure-jnp oracle for the Zones pair kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pair_count_ref(a, b, cos_min, *, exclude_self: bool = False):
    """a: [M,3], b: [N,3] unit vectors. Count of (i,j) with a_i . b_j >= cos_min.

    exclude_self: drop the diagonal (use when a and b are the same block).
    """
    dots = a.astype(jnp.float32) @ b.astype(jnp.float32).T
    ok = dots >= cos_min
    if exclude_self:
        M, N = ok.shape
        ok = ok & ~jnp.eye(M, N, dtype=bool)
    return jnp.sum(ok, dtype=jnp.int32)


def pair_hist_ref(a, b, cos_edges, *, exclude_self: bool = False):
    """Cumulative counts per edge: out[k] = #{(i,j): dot >= cos_edges[k]}.

    cos_edges descending in angle (i.e. cos ascending? NO: theta_k ascending =>
    cos_edges descending). The differential histogram for bin (theta_{k-1},theta_k]
    is out[k] - out[k-1].
    """
    dots = a.astype(jnp.float32) @ b.astype(jnp.float32).T
    if exclude_self:
        M, N = dots.shape
        dots = jnp.where(jnp.eye(M, N, dtype=bool), -2.0, dots)
    return jnp.sum(dots[None, :, :] >= cos_edges[:, None, None],
                   axis=(1, 2), dtype=jnp.int32)
