from repro.kernels.zones_pairs.ops import pair_count, pair_hist
