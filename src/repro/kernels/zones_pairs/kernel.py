"""Pallas TPU kernel: blockwise pair search for the Zones algorithm.

The compute hot spot of the paper's Neighbor Searching / Neighbor Statistics apps:
for two tiles of unit vectors, form the [TM, TN] dot-product tile on the MXU and
reduce (count >= cos_min, or cumulative per-edge counts for the histogram app).
The [TM, TN] score tile lives only in VMEM — the analogue of the paper's insight that
the reducer should never write O(n^2) intermediates.

Grid is (M/TM, N/TN); per-tile partial results land in an [gm, gn] (or [gm, gn, NB])
output that the caller sums — keeping the kernel free of cross-tile accumulation.

The ``*_masked_pallas`` variants add a leading *partition* grid axis
(grid ``(P, M/TM, N/TN)``) with per-partition real counts ``n_a``/``n_b``:
rows/cols beyond the real count are masked out in-kernel, so capacity padding
contributes zero regardless of the pad fill — the engine="device" batched
reduce runs every partition of a size tier in ONE kernel launch instead of a
sequential ``lax.map``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TM = 256
TN = 256


def _count_kernel(a_ref, b_ref, cmin_ref, o_ref, *, exclude_self: bool):
    a = a_ref[...].astype(jnp.float32)              # [TM, 3->pad]
    b = b_ref[...].astype(jnp.float32)              # [TN, 3->pad]
    dots = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    ok = dots >= cmin_ref[0]
    if exclude_self:
        i = pl.program_id(0)
        j = pl.program_id(1)
        tm, tn = dots.shape
        ri = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0) + i * tm
        rj = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1) + j * tn
        ok = ok & (ri != rj)
    o_ref[0, 0] = jnp.sum(ok.astype(jnp.int32))


def _hist_kernel(a_ref, b_ref, edges_ref, o_ref, *, exclude_self: bool):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    dots = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    if exclude_self:
        i = pl.program_id(0)
        j = pl.program_id(1)
        tm, tn = dots.shape
        ri = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0) + i * tm
        rj = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1) + j * tn
        dots = jnp.where(ri == rj, -2.0, dots)
    edges = edges_ref[...]                           # [NB]
    nb = edges.shape[0]

    def bin_body(k, _):
        o_ref[0, 0, k] = jnp.sum((dots >= edges[k]).astype(jnp.int32))
        return 0

    jax.lax.fori_loop(0, nb, bin_body, 0)


def _pad3(x):
    """Pad the coordinate dim 3 -> 128 (lane alignment); zeros don't affect dots."""
    pad = [(0, 0)] * (x.ndim - 1) + [(0, 128 - x.shape[-1])]
    return jnp.pad(x, pad)


def pair_count_pallas(a, b, cos_min, *, exclude_self: bool = False,
                      tm: int = TM, tn: int = TN, interpret: bool = False):
    M, N = a.shape[0], b.shape[0]
    assert M % tm == 0 and N % tn == 0, (M, N, tm, tn)
    gm, gn = M // tm, N // tn
    cmin = jnp.full((1,), cos_min, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_count_kernel, exclude_self=exclude_self),
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((tm, 128), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, 128), lambda i, j: (j, 0)),
                  pl.BlockSpec((1,), lambda i, j: (0,))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm, gn), jnp.int32),
        interpret=interpret,
    )(_pad3(a), _pad3(b), cmin)
    return jnp.sum(out, dtype=jnp.int32)


def pair_hist_pallas(a, b, cos_edges, *, exclude_self: bool = False,
                     tm: int = TM, tn: int = TN, interpret: bool = False):
    M, N = a.shape[0], b.shape[0]
    assert M % tm == 0 and N % tn == 0, (M, N, tm, tn)
    gm, gn = M // tm, N // tn
    nbins = cos_edges.shape[0]
    out = pl.pallas_call(
        functools.partial(_hist_kernel, exclude_self=exclude_self),
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((tm, 128), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, 128), lambda i, j: (j, 0)),
                  pl.BlockSpec((nbins,), lambda i, j: (0,))],
        out_specs=pl.BlockSpec((1, 1, nbins), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((gm, gn, nbins), jnp.int32),
        interpret=interpret,
    )(_pad3(a), _pad3(b), jnp.asarray(cos_edges, jnp.float32))
    return jnp.sum(out, axis=(0, 1), dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Masked-batched variants: leading partition grid axis + n_a/n_b masking
# ---------------------------------------------------------------------------

def _fit_tile(C: int, t: int) -> int:
    """Largest divisor of C that is <= t — keeps VMEM blocks bounded even
    when a tier capacity isn't a multiple of the default tile (a whole-axis
    fallback would materialize an [C, C] score tile)."""
    t = min(t, C)
    while C % t:
        t -= 1
    return t


def _tile_validity(na, nb, i, j, tm, tn):
    """[tm, tn] bool: (row, col) is a real (non-padded) pair of this tile."""
    ri = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0) + i * tm
    rj = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1) + j * tn
    return (ri < na) & (rj < nb)


def _count_masked_kernel(a_ref, b_ref, cmin_ref, na_ref, nb_ref, o_ref):
    i, j = pl.program_id(1), pl.program_id(2)
    a = a_ref[0].astype(jnp.float32)                # [tm, 128]
    b = b_ref[0].astype(jnp.float32)                # [tn, 128]
    o_ref[0, 0, 0] = 0

    @pl.when((pl.program_id(1) * a.shape[0] < na_ref[0])
             & (pl.program_id(2) * b.shape[0] < nb_ref[0]))
    def _():                                        # skip all-padding tiles
        dots = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        ok = (dots >= cmin_ref[0]) & _tile_validity(
            na_ref[0], nb_ref[0], i, j, *dots.shape)
        o_ref[0, 0, 0] = jnp.sum(ok.astype(jnp.int32))


def _hist_masked_kernel(a_ref, b_ref, edges_ref, na_ref, nb_ref, o_ref):
    i, j = pl.program_id(1), pl.program_id(2)
    a = a_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)
    dots = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dots = jnp.where(_tile_validity(na_ref[0], nb_ref[0], i, j, *dots.shape),
                     dots, -2.0)
    edges = edges_ref[...]                           # [NB]

    def bin_body(k, _):
        o_ref[0, 0, 0, k] = jnp.sum((dots >= edges[k]).astype(jnp.int32))
        return 0

    jax.lax.fori_loop(0, edges.shape[0], bin_body, 0)


def pair_count_masked_pallas(a, b, n_a, n_b, cos_min, *, tm: int = TM,
                             tn: int = TN, interpret: bool = False):
    """a: [P,M,3], b: [P,N,3] (any float dtype), n_a/n_b: [P] int32 real
    counts. -> total masked pair count (scalar int32)."""
    P, M, _ = a.shape
    N = b.shape[1]
    tm, tn = _fit_tile(M, tm), _fit_tile(N, tn)
    gm, gn = M // tm, N // tn
    cmin = jnp.full((1,), cos_min, jnp.float32)
    out = pl.pallas_call(
        _count_masked_kernel,
        grid=(P, gm, gn),
        in_specs=[pl.BlockSpec((1, tm, 128), lambda p, i, j: (p, i, 0)),
                  pl.BlockSpec((1, tn, 128), lambda p, i, j: (p, j, 0)),
                  pl.BlockSpec((1,), lambda p, i, j: (0,)),
                  pl.BlockSpec((1,), lambda p, i, j: (p,)),
                  pl.BlockSpec((1,), lambda p, i, j: (p,))],
        out_specs=pl.BlockSpec((1, 1, 1), lambda p, i, j: (p, i, j)),
        out_shape=jax.ShapeDtypeStruct((P, gm, gn), jnp.int32),
        interpret=interpret,
    )(_pad3(a), _pad3(b), cmin, jnp.asarray(n_a, jnp.int32),
      jnp.asarray(n_b, jnp.int32))
    return jnp.sum(out, dtype=jnp.int32)


def pair_hist_masked_pallas(a, b, n_a, n_b, cos_edges, *, tm: int = TM,
                            tn: int = TN, interpret: bool = False):
    """Masked-batched cumulative per-edge counts, summed over partitions."""
    P, M, _ = a.shape
    N = b.shape[1]
    tm, tn = _fit_tile(M, tm), _fit_tile(N, tn)
    gm, gn = M // tm, N // tn
    nbins = cos_edges.shape[0]
    out = pl.pallas_call(
        _hist_masked_kernel,
        grid=(P, gm, gn),
        in_specs=[pl.BlockSpec((1, tm, 128), lambda p, i, j: (p, i, 0)),
                  pl.BlockSpec((1, tn, 128), lambda p, i, j: (p, j, 0)),
                  pl.BlockSpec((nbins,), lambda p, i, j: (0,)),
                  pl.BlockSpec((1,), lambda p, i, j: (p,)),
                  pl.BlockSpec((1,), lambda p, i, j: (p,))],
        out_specs=pl.BlockSpec((1, 1, 1, nbins), lambda p, i, j: (p, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((P, gm, gn, nbins), jnp.int32),
        interpret=interpret,
    )(_pad3(a), _pad3(b), jnp.asarray(cos_edges, jnp.float32),
      jnp.asarray(n_a, jnp.int32), jnp.asarray(n_b, jnp.int32))
    return jnp.sum(out, axis=(0, 1, 2), dtype=jnp.int32)
