"""Pallas TPU kernel: blockwise pair search for the Zones algorithm.

The compute hot spot of the paper's Neighbor Searching / Neighbor Statistics apps:
for two tiles of unit vectors, form the [TM, TN] dot-product tile on the MXU and
reduce (count >= cos_min, or cumulative per-edge counts for the histogram app).
The [TM, TN] score tile lives only in VMEM — the analogue of the paper's insight that
the reducer should never write O(n^2) intermediates.

Grid is (M/TM, N/TN); per-tile partial results land in an [gm, gn] (or [gm, gn, NB])
output that the caller sums — keeping the kernel free of cross-tile accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TM = 256
TN = 256


def _count_kernel(a_ref, b_ref, cmin_ref, o_ref, *, exclude_self: bool):
    a = a_ref[...].astype(jnp.float32)              # [TM, 3->pad]
    b = b_ref[...].astype(jnp.float32)              # [TN, 3->pad]
    dots = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    ok = dots >= cmin_ref[0]
    if exclude_self:
        i = pl.program_id(0)
        j = pl.program_id(1)
        tm, tn = dots.shape
        ri = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0) + i * tm
        rj = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1) + j * tn
        ok = ok & (ri != rj)
    o_ref[0, 0] = jnp.sum(ok.astype(jnp.int32))


def _hist_kernel(a_ref, b_ref, edges_ref, o_ref, *, exclude_self: bool):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    dots = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    if exclude_self:
        i = pl.program_id(0)
        j = pl.program_id(1)
        tm, tn = dots.shape
        ri = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0) + i * tm
        rj = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1) + j * tn
        dots = jnp.where(ri == rj, -2.0, dots)
    edges = edges_ref[...]                           # [NB]
    nb = edges.shape[0]

    def bin_body(k, _):
        o_ref[0, 0, k] = jnp.sum((dots >= edges[k]).astype(jnp.int32))
        return 0

    jax.lax.fori_loop(0, nb, bin_body, 0)


def _pad3(x):
    """Pad the coordinate dim 3 -> 128 (lane alignment); zeros don't affect dots."""
    return jnp.pad(x, ((0, 0), (0, 125)))


def pair_count_pallas(a, b, cos_min, *, exclude_self: bool = False,
                      tm: int = TM, tn: int = TN, interpret: bool = False):
    M, N = a.shape[0], b.shape[0]
    assert M % tm == 0 and N % tn == 0, (M, N, tm, tn)
    gm, gn = M // tm, N // tn
    cmin = jnp.full((1,), cos_min, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_count_kernel, exclude_self=exclude_self),
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((tm, 128), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, 128), lambda i, j: (j, 0)),
                  pl.BlockSpec((1,), lambda i, j: (0,))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm, gn), jnp.int32),
        interpret=interpret,
    )(_pad3(a), _pad3(b), cmin)
    return jnp.sum(out, dtype=jnp.int32)


def pair_hist_pallas(a, b, cos_edges, *, exclude_self: bool = False,
                     tm: int = TM, tn: int = TN, interpret: bool = False):
    M, N = a.shape[0], b.shape[0]
    assert M % tm == 0 and N % tn == 0, (M, N, tm, tn)
    gm, gn = M // tm, N // tn
    nbins = cos_edges.shape[0]
    out = pl.pallas_call(
        functools.partial(_hist_kernel, exclude_self=exclude_self),
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((tm, 128), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, 128), lambda i, j: (j, 0)),
                  pl.BlockSpec((nbins,), lambda i, j: (0,))],
        out_specs=pl.BlockSpec((1, 1, nbins), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((gm, gn, nbins), jnp.int32),
        interpret=interpret,
    )(_pad3(a), _pad3(b), jnp.asarray(cos_edges, jnp.float32))
    return jnp.sum(out, axis=(0, 1), dtype=jnp.int32)
