"""Jit'd wrapper selecting Pallas (TPU) or the jnp reference (CPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.zones_pairs.kernel import (pair_count_masked_pallas,
                                              pair_count_pallas,
                                              pair_hist_masked_pallas,
                                              pair_hist_pallas)
from repro.kernels.zones_pairs.ref import pair_count_ref, pair_hist_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("exclude_self", "use_pallas"))
def pair_count(a, b, cos_min, *, exclude_self: bool = False,
               use_pallas: bool | None = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return pair_count_pallas(a, b, cos_min, exclude_self=exclude_self,
                                 interpret=not _on_tpu())
    return pair_count_ref(a, b, cos_min, exclude_self=exclude_self)


@functools.partial(jax.jit, static_argnames=("exclude_self", "use_pallas"))
def pair_hist(a, b, cos_edges, *, exclude_self: bool = False,
              use_pallas: bool | None = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return pair_hist_pallas(a, b, cos_edges, exclude_self=exclude_self,
                                interpret=not _on_tpu())
    return pair_hist_ref(a, b, cos_edges, exclude_self=exclude_self)


# Masked-batched variants (the engine="device" reduce): one call covers a
# whole size tier of partitions; padded rows are masked via n_a/n_b, never
# via pad-value tricks. On TPU: Pallas kernels with a leading partition grid
# axis. Elsewhere: the z-banded blocked reduce (``blocked.py``) — same
# results, tile pairs outside the z band pruned, fixed-shape chunks so the
# XLA compile is shared across codecs, radii, and job shapes. These run
# eagerly (the blocked path plans its blocks on the host), NOT under jit.
#
# Traceability: the Pallas variants are pure traced jax and can run inside
# a ``shard_map`` region (the mesh-sharded device reduce; interpret mode
# included), and both tolerate all-padding shards (every n_a/n_b zero — the
# ``pl.when`` guard / validity mask zero out every tile). The blocked path
# CANNOT be traced (host-side block planning); ``masked_uses_pallas``
# resolves which one a given ``use_pallas`` setting lands on, so the engine
# knows whether the sharded reduce may trace the kernel or must slice
# shards eagerly.


def masked_uses_pallas(use_pallas: bool | None = None) -> bool:
    """Resolve a ``use_pallas`` setting: True -> traceable Pallas masked
    kernels, False -> the eager-only z-banded blocked engine."""
    return _on_tpu() if use_pallas is None else use_pallas


def pair_count_masked(a, b, n_a, n_b, cos_min, *,
                      use_pallas: bool | None = None):
    if masked_uses_pallas(use_pallas):
        return pair_count_masked_pallas(a, b, n_a, n_b, cos_min,
                                        interpret=not _on_tpu())
    from repro.kernels.zones_pairs.blocked import pair_count_blocked
    return pair_count_blocked(a, b, n_a, n_b, cos_min)


def pair_hist_masked(a, b, n_a, n_b, cos_edges, *,
                     use_pallas: bool | None = None):
    if masked_uses_pallas(use_pallas):
        return pair_hist_masked_pallas(a, b, n_a, n_b, cos_edges,
                                       interpret=not _on_tpu())
    from repro.kernels.zones_pairs.blocked import pair_hist_blocked
    return pair_hist_blocked(a, b, n_a, n_b, cos_edges)
