"""Jit'd wrapper selecting Pallas (TPU) or the jnp reference (CPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.zones_pairs.kernel import pair_count_pallas, pair_hist_pallas
from repro.kernels.zones_pairs.ref import pair_count_ref, pair_hist_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("exclude_self", "use_pallas"))
def pair_count(a, b, cos_min, *, exclude_self: bool = False,
               use_pallas: bool | None = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return pair_count_pallas(a, b, cos_min, exclude_self=exclude_self,
                                 interpret=not _on_tpu())
    return pair_count_ref(a, b, cos_min, exclude_self=exclude_self)


@functools.partial(jax.jit, static_argnames=("exclude_self", "use_pallas"))
def pair_hist(a, b, cos_edges, *, exclude_self: bool = False,
              use_pallas: bool | None = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return pair_hist_pallas(a, b, cos_edges, exclude_self=exclude_self,
                                interpret=not _on_tpu())
    return pair_hist_ref(a, b, cos_edges, exclude_self=exclude_self)
