"""Serving: jit'd prefill/decode steps + a slot-based continuous-batching engine.

The decode step is what ``decode_*`` / ``long_*`` shapes lower in the dry-run: one new
token against a KV cache of ``seq_len`` (cache donated — the direct-I/O analogue:
in-place cache update, no copy).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.models import model as mdl
from repro.parallel.sharding import make_rules, use_mesh


def make_prefill_step(cfg: ArchConfig, rc: RunConfig, mesh, max_len: int):
    rules = make_rules(mesh, pod_param_mode=rc.pod_param_mode)

    def prefill_fn(params, biases, batch):
        with use_mesh(mesh, rules):
            return mdl.prefill(cfg, rc, params, biases, batch, max_len)

    return jax.jit(prefill_fn), rules


def make_decode_step(cfg: ArchConfig, rc: RunConfig, mesh):
    rules = make_rules(mesh, pod_param_mode=rc.pod_param_mode)

    def decode_fn(params, biases, cache, token, pos):
        with use_mesh(mesh, rules):
            return mdl.decode_step(cfg, rc, params, biases, cache, token, pos)

    return jax.jit(decode_fn, donate_argnums=(2,)), rules


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot continuous batching: finished slots are refilled from the queue
    without stopping the running batch (slot-level, not token-level, scheduling)."""

    def __init__(self, cfg: ArchConfig, rc: RunConfig, params, biases, mesh,
                 *, slots: int = 4, max_len: int = 256, eos: int = -1):
        self.cfg, self.rc = cfg, rc
        self.params, self.biases = params, biases
        self.mesh = mesh
        self.slots = slots
        self.max_len = max_len
        self.eos = eos
        self.decode, self.rules = make_decode_step(cfg, rc, mesh)
        self.queue: list[Request] = []
        self.active: list[Request | None] = [None] * slots
        with use_mesh(mesh, self.rules):
            self.cache = mdl.init_cache(cfg, slots, max_len)
        self.pos = 0
        self.cur = jnp.zeros((slots, 1), jnp.int32)
        self.closed = False

    def submit(self, req: Request):
        if self.closed:
            raise RuntimeError(
                "ServeEngine is closed: run() drained its queue (or the KV "
                "cache is full) — a submission now would silently never be "
                "served")
        self.queue.append(req)

    def _fill_slots(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req

    def run(self, max_steps: int = 512, greedy: bool = True):
        """Prefill is emulated by feeding prompt tokens through decode (slot-wise
        simplicity; the batched prefill path is exercised separately)."""
        self._fill_slots()
        # position cursor is shared across slots (simplification: left-aligned)
        feed = [list(r.prompt) if r else [] for r in self.active]
        steps = 0
        while steps < max_steps and (any(self.active) or self.queue):
            tok = np.zeros((self.slots, 1), np.int32)
            for i, r in enumerate(self.active):
                if r is None:
                    continue
                if feed[i]:
                    tok[i, 0] = feed[i].pop(0)
                elif r.out:
                    tok[i, 0] = r.out[-1]
                elif r.prompt:
                    tok[i, 0] = r.prompt[-1]
            logits, self.cache = self.decode(self.params, self.biases,
                                             self.cache, jnp.asarray(tok),
                                             jnp.int32(self.pos))
            self.pos += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for i, r in enumerate(self.active):
                if r is None or feed[i]:
                    continue
                t = int(nxt[i])
                r.out.append(t)
                if len(r.out) >= r.max_new or t == self.eos:
                    r.done = True
                    self.active[i] = None
            self._fill_slots()
            for i, r in enumerate(self.active):
                if r is not None and not r.out and not feed[i] and r.prompt:
                    feed[i] = list(r.prompt)       # newly seated request
            steps += 1
            if self.pos >= self.max_len - 1:
                break
        # drained (or cache exhausted): later submissions could never be
        # served by this engine instance, so reject them at the door
        if self.pos >= self.max_len - 1 or not (any(self.active)
                                                or self.queue):
            self.closed = True
        return steps
