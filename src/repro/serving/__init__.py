from repro.serving.engine import (
    ServeEngine, Request, make_prefill_step, make_decode_step,
)
from repro.serving.mr_service import MRQueryService, MRRequest
