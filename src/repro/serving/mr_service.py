"""MapReduce query service: a resident sharded catalog serving online queries.

The LM side already serves continuously (``serving/engine.py``'s slot-based
``ServeEngine``); this is its MapReduce twin, shaped for the workload the
paper actually argues about — a long-running node kept busy by a stream of
many small data-intensive requests against shared resident data (the HDFS
workload-consolidation result: throughput hinges on co-scheduling, not on
one-shot batch jobs):

- the catalog is loaded, mapped, and shuffled ONCE (``shuffle_once`` ->
  ``ResidentCatalog``): its tiered wire-dtype partitions stay device-resident
  (psum-sharded over a ``data``-axis mesh when one is given) across every
  request the service will ever answer;
- queries enter a submit queue and an admission window groups them into
  micro-batches — count-triggered at ``max_batch`` or time-triggered after
  ``max_wait_s``, whichever fires first, the same slot-fill trade
  ``ServeEngine`` makes — then each batch is grouped per catalog and
  COALESCED (identical jobs run once; distinct compatible jobs fuse into one
  batched reduce pass, the ``run_jobs`` multi-job path), so N queries cost
  one shuffle ever + ~one reduce pass per distinct job;
- jit/shard_map caches persist across requests for free: the module-level
  caches in ``mapreduce/job.py`` key on (reducers, codec, mesh), so a
  recurring query mix stops retracing after its first batch;
- every request carries a ``RequestStats`` (queue wait, batch wall, latency);
  ``latency_summary`` turns the stream into qps/p50/p99 rows (the
  ``fig5_service`` benchmark), and per-batch walls feed an optional
  ``straggler_monitor=`` hook with the same ``record(index, wall_s)``
  contract as the streaming executor — ``ft.SpeculativePolicy`` spots slow
  batches in serving mode exactly as it spots slow splits in batch mode.

    svc = MRQueryService(max_batch=16, max_wait_s=0.002)
    svc.load_catalog("sky", xyz, ZonePartitioner(0.02), codec="int16")
    with svc:                              # background admission thread
        reqs = [svc.submit(neighbor_search_job(r, partitioner=part,
                                               codec="int16"), catalog="sky")
                for r in radii]
        outs = [r.result() for r in reqs]
    svc.latency_summary()                  # {"qps": ..., "p99_ms": ...}
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

from repro.mapreduce.codecs import get_codec
from repro.mapreduce.instrumentation import RequestStats, latency_summary
from repro.mapreduce.job import (MapReduceJob, ResidentCatalog, shuffle_once)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer


def _job_key(job: MapReduceJob) -> tuple:
    """Equality key for request coalescing: two submissions with this key
    are THE SAME query and share one reduce. Codec instances (e.g. the
    wordcount job's per-vocab ``Int16Codec``) compare by parameters, not
    identity, so independently-built identical jobs still coalesce."""
    c = get_codec(job.codec)
    return (job.name, job.partitioner, job.reducer, job.tile,
            type(c).__name__, tuple(sorted(vars(c).items())))


@dataclasses.dataclass
class MRRequest:
    """One queued query: a ``MapReduceJob`` against a named resident
    catalog. ``result()`` blocks until the admitting micro-batch completes;
    ``stats`` is the request's ``RequestStats`` once served."""

    rid: int
    job: MapReduceJob
    catalog: str
    t_submit: float
    output: object = None
    error: BaseException | None = None
    stats: RequestStats | None = None
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None):
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} still queued/running "
                               f"after {timeout}s")
        if self.error is not None:
            raise self.error
        return self.output


class MRQueryService:
    """Long-running MapReduce query service over resident shuffled catalogs.

    Two execution modes share one admission path: ``start()`` (or the
    context manager) runs micro-batches on a background thread as windows
    fire; ``run_pending()`` drains synchronously — deterministic, and its
    ``batch_sizes=`` override replays ANY partition of the queue into
    micro-batches (the batching-determinism property tests use this).
    ``close()`` rejects further submits, serves what is queued, and joins
    the worker; like ``ServeEngine`` after ``run()`` drains, a closed
    service raises on ``submit``.
    """

    def __init__(self, *, mesh=None, max_batch: int = 16,
                 max_wait_s: float = 0.002, straggler_monitor=None,
                 n_lanes: int = 1, lane_chaos=None,
                 clock=time.perf_counter, metrics: MetricsRegistry = None):
        self.mesh = mesh
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.straggler_monitor = straggler_monitor
        self.n_lanes = int(n_lanes)
        self.lane_chaos = lane_chaos
        self.clock = clock
        # live service metrics (obs/metrics.py): per-instance by default so
        # two services don't mix counters; pass a shared registry to scrape
        # several services off one page
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._t_first_submit: float | None = None
        self.catalogs: dict[str, ResidentCatalog] = {}
        self.request_stats: list[RequestStats] = []
        self.batches: list[dict] = []       # per-batch records (size, wall, ...)
        self.closed = False
        self._queue: deque[MRRequest] = deque()
        self._cond = threading.Condition()
        self._blk = threading.Lock()        # batches/request_stats bookkeeping
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._pool = None                   # LanePool when n_lanes > 1
        self._nbatch = 0                    # lane-pool batch keys
        self._rid = 0

    # -- catalog management -------------------------------------------------

    def load_catalog(self, name: str, items, partitioner, *,
                     codec="identity", tile: int = 256,
                     pad_value: float = 0.0) -> ResidentCatalog:
        """Map + shuffle ``items`` once into device-resident tiers under
        ``name``; every later query against ``name`` is a pure reduce."""
        if self.closed:
            raise RuntimeError("MRQueryService is closed")
        cat = shuffle_once(partitioner, items, codec=codec, tile=tile,
                           pad_value=pad_value, mesh=self.mesh)
        self.catalogs[name] = cat
        return cat

    def catalog(self, name: str = "default") -> ResidentCatalog:
        return self.catalogs[name]

    # -- submission ---------------------------------------------------------

    def submit(self, job: MapReduceJob, *,
               catalog: str = "default") -> MRRequest:
        """Enqueue one query. Validates the job against the target catalog's
        shuffle signature HERE (fail fast at the caller, not in the worker);
        raises RuntimeError once the service is closed — submissions would
        otherwise enqueue into a dead service and never complete."""
        cat = self.catalogs.get(catalog)
        if cat is None:
            raise KeyError(f"no catalog {catalog!r} loaded "
                           f"(have {sorted(self.catalogs)}); "
                           f"call load_catalog() first")
        cat.validate([job])
        with self._cond:
            if self.closed:
                raise RuntimeError(
                    "MRQueryService is closed: submit() after close() "
                    "would never be served (same guard as ServeEngine "
                    "after run() drains)")
            req = MRRequest(self._rid, job, catalog, self.clock())
            self._rid += 1
            self._queue.append(req)
            if self._t_first_submit is None:
                self._t_first_submit = req.t_submit
            self.metrics.counter("mr_requests").inc()
            self.metrics.gauge("mr_queue_depth").set(len(self._queue))
            self._cond.notify()
        return req

    @property
    def pending(self) -> int:
        with self._cond:
            return len(self._queue)

    # -- admission / batching policy ----------------------------------------

    def _admit(self) -> list[MRRequest]:
        """Take one micro-batch off the queue (worker thread): the first
        waiting request opens an admission window that closes after
        ``max_wait_s`` OR as soon as ``max_batch`` requests are queued —
        waiting fills the batch (throughput), the deadline bounds queue
        wait (latency). ServeEngine's slot-fill loop, for reduces."""
        with self._cond:
            while not self._queue and not self._stop.is_set():
                self._cond.wait(timeout=0.05)
            if not self._queue:
                return []
            deadline = self.clock() + self.max_wait_s
            while len(self._queue) < self.max_batch and not self._stop.is_set():
                left = deadline - self.clock()
                if left <= 0:
                    break
                self._cond.wait(timeout=left)
            take = min(self.max_batch, len(self._queue))
            return [self._queue.popleft() for _ in range(take)]

    def _run_batch(self, batch: list[MRRequest]) -> None:
        """Serve one admitted micro-batch: group by catalog, coalesce
        duplicate jobs, one fused batched reduce per catalog group, then
        stamp RequestStats / wake waiters / feed the straggler hook.

        Failure isolation: dedupe maps many requests onto one fused
        ``cat.run``, so a single poison job used to surface its error
        through EVERY waiter in the group. Now a failed fused pass falls
        back to running each distinct job alone — only the requests mapped
        to the actually-failing job see its error; batch-mates are served.
        Bookkeeping appends under a lock so lane-concurrent batches can't
        interleave records."""
        tr = get_tracer()
        t_admit = self.clock()
        t_span0 = time.perf_counter()
        by_cat: dict[str, list[MRRequest]] = {}
        for r in batch:
            by_cat.setdefault(r.catalog, []).append(r)
        n_unique = 0
        for cname, reqs in by_cat.items():
            cat = self.catalogs[cname]
            uniq_keys: list[tuple] = []
            uniq_jobs: list[MapReduceJob] = []
            slots: list[int] = []       # per-request index into uniq_jobs
            for r in reqs:
                k = _job_key(r.job)
                try:
                    slots.append(uniq_keys.index(k))
                except ValueError:
                    slots.append(len(uniq_jobs))
                    uniq_keys.append(k)
                    uniq_jobs.append(r.job)
            n_unique += len(uniq_jobs)
            try:
                results = cat.run(uniq_jobs)
                outs = [(res.output, None) for res in results]
            except BaseException:
                # the fused pass died: isolate per distinct job so one
                # poison query cannot fail its coalesced batch-mates
                outs = []
                for job in uniq_jobs:
                    try:
                        outs.append((cat.run([job])[0].output, None))
                    except BaseException as e:
                        outs.append((None, e))
            for r, s in zip(reqs, slots):
                out, err = outs[s]
                if err is None:
                    r.output = out
                else:
                    r.error = err
        t_done = self.clock()
        wall = t_done - t_admit
        m = self.metrics
        with self._blk:
            bidx = len(self.batches)
            self.batches.append({"batch": bidx, "size": len(batch),
                                 "n_unique": n_unique, "wall_s": wall})
            if self.straggler_monitor is not None:
                self.straggler_monitor.record(bidx, wall)
            for r in batch:
                r.stats = RequestStats(
                    rid=r.rid, job=r.job.name, catalog=r.catalog,
                    batch_index=bidx, batch_size=len(batch),
                    n_unique=n_unique, t_submit_s=r.t_submit,
                    queue_wait_s=t_admit - r.t_submit,
                    batch_wall_s=wall, latency_s=t_done - r.t_submit)
                self.request_stats.append(r.stats)
                m.histogram("mr_latency_ms").observe(r.stats.latency_s * 1e3)
                m.histogram("mr_queue_wait_ms").observe(
                    r.stats.queue_wait_s * 1e3)
            m.counter("mr_batches").inc()
            m.counter("mr_requests_served").inc(len(batch))
            n_served = len(self.request_stats)
            t_first = self._t_first_submit
        if tr.enabled:
            tr.record("service-batch", t_span0, time.perf_counter(),
                      cat="service", batch=bidx, size=len(batch),
                      n_unique=n_unique,
                      rids=[r.rid for r in batch[:32]])
        span = (t_done - t_first) if t_first is not None else 0.0
        if span > 1e-9:
            m.gauge("mr_qps").set(n_served / span)
        m.gauge("mr_queue_depth").set(self.pending)
        for r in batch:
            r._done.set()

    # -- execution: synchronous drain or background serving thread ----------

    def run_pending(self, *, batch_sizes=None) -> int:
        """Synchronously drain the queue in micro-batches. ``batch_sizes``
        forces an explicit partition of the queue (replay / determinism
        tests); default chunks by ``max_batch`` with no admission wait.
        -> number of requests served."""
        sizes = iter(batch_sizes if batch_sizes is not None else [])
        served = 0
        while True:
            with self._cond:
                if not self._queue:
                    break
                k = next(sizes, self.max_batch)
                k = max(1, min(int(k), len(self._queue)))
                batch = [self._queue.popleft() for _ in range(k)]
            self._run_batch(batch)
            served += len(batch)
        return served

    def _serve_loop(self) -> None:
        """Admission loop. With a lane pool, admitted micro-batches are
        SUBMITTED and run concurrently across lanes (they no longer queue
        behind one stream); a lane death shrinks the pool and requeues the
        batch onto the survivors instead of killing the service."""
        while True:
            t0 = time.perf_counter()
            batch = self._admit()
            if batch:
                tr = get_tracer()
                if tr.enabled:
                    # covers waiting for the first request plus the
                    # admission window it opened
                    tr.record("service-admit", t0, time.perf_counter(),
                              cat="service", size=len(batch))
                if self._pool is not None:
                    key, self._nbatch = self._nbatch, self._nbatch + 1
                    self._pool.submit(
                        key, (lambda b: lambda cancel: self._run_batch(b))(
                            batch))
                else:
                    self._run_batch(batch)
            elif self._stop.is_set():
                return

    def start(self) -> "MRQueryService":
        """Start the background admission/serving thread (idempotent); with
        ``n_lanes > 1`` also start the concurrent-batch lane pool."""
        if self.closed:
            raise RuntimeError("MRQueryService is closed")
        if self._thread is None:
            if self.n_lanes > 1 and self._pool is None:
                from repro.mapreduce.executor import LanePool
                self._pool = LanePool(self.n_lanes, chaos=self.lane_chaos,
                                      max_retries=0, name="mr-batch")
            self._stop.clear()
            self._thread = threading.Thread(target=self._serve_loop,
                                            name="mr-service", daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        """Reject further submits, serve everything already queued, and
        stop the worker (and the lane pool, asserting its threads joined).
        Idempotent; also the context-manager exit."""
        with self._cond:
            self.closed = True
            self._stop.set()
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=60.0)
            self._thread = None
        self.run_pending()               # anything the worker left behind
        if self._pool is not None:
            pool, self._pool = self._pool, None
            try:
                pool.drain()             # in-flight lane batches finish
            finally:
                pool.shutdown()          # raises on leaked lane threads

    def __enter__(self) -> "MRQueryService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- accounting ---------------------------------------------------------

    def latency_summary(self) -> dict:
        """qps + p50/p99 latency over everything served so far."""
        return latency_summary(self.request_stats)
