"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8 MoE, MTP [arXiv:2412.19437].

61L, d_model=7168, 128 heads (MLA: q_lora=1536, kv_lora=512, rope=64, nope=128, v=128),
routed expert d_ff=2048, vocab=129280. First 3 layers dense (d_ff=18432); aux-loss-free
sigmoid+bias routing with routed_scaling=2.5; one shared expert; optional depth-1 MTP.
Optimizer defaults to Adafactor so 671B of optimizer state fits 512 chips of HBM.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,               # dense FFN width for the first `start_layer` layers
    vocab=129280,
    pattern=("attn",),
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(
        n_experts=256, top_k=8, d_ff_expert=2048,
        n_shared=1, d_ff_shared=2048,
        router="sigmoid_bias", routed_scaling=2.5,
        start_layer=3, capacity_factor=1.25, chunk_tokens=2048,
    ),
    mtp=True,
    optimizer="adafactor",
    source="arXiv:2412.19437",
)
