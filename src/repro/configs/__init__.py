from repro.configs.base import (
    ArchConfig, MoEConfig, SSMConfig, RGLRUConfig, MLAConfig,
    RunConfig, ShapeConfig, SHAPES, cell_is_applicable, round_up,
)
from repro.configs.registry import ARCHS, get_arch, get_shape, live_cells

__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "RGLRUConfig", "MLAConfig",
    "RunConfig", "ShapeConfig", "SHAPES", "cell_is_applicable", "round_up",
    "ARCHS", "get_arch", "get_shape", "live_cells",
]
