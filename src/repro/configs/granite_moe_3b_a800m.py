"""granite-moe-3b-a800m — 40 experts top-8 [hf:ibm-granite/granite-3.0-3b-a800m-base].

32L, d_model=1536, 24 heads (GQA kv=8, head_dim=64), expert d_ff=512 (SwiGLU),
vocab=49155, MoE 40 experts top-8 on every layer. Experts padded 40->48 so the expert
axis shards evenly over model=16 (8 masked experts the router can never select).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    pattern=("attn",),
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=40, top_k=8, d_ff_expert=512,
        router="softmax_topk", aux_loss_coef=0.01,
        capacity_factor=1.25, n_expert_pad=8, chunk_tokens=4096,
    ),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
)
