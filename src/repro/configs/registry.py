"""Registry of assigned architectures (``--arch <id>``)."""
from __future__ import annotations

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig, cell_is_applicable

from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.starcoder2_7b import CONFIG as _starcoder2
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.recurrentgemma_2b import CONFIG as _recurrentgemma
from repro.configs.deepseek_v3_671b import CONFIG as _deepseek
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.internvl2_2b import CONFIG as _internvl2

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _mamba2, _tinyllama, _olmo, _gemma2, _starcoder2,
        _musicgen, _recurrentgemma, _deepseek, _granite, _internvl2,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def live_cells() -> list[tuple[ArchConfig, ShapeConfig]]:
    """All applicable (arch, shape) dry-run cells."""
    out = []
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            ok, _ = cell_is_applicable(cfg, shape)
            if ok:
                out.append((cfg, shape))
    return out
