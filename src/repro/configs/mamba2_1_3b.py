"""mamba2-1.3b — SSD (state-space duality) [arXiv:2405.21060].

48 blocks, d_model=2048, attention-free, d_ff=0 (Mamba-2 blocks only), vocab=50280,
ssm_state=128. expand=2 -> d_inner=4096, head_dim=64 -> 64 SSD heads.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,            # SSD heads = d_inner / head_dim
    n_kv_heads=64,
    d_ff=0,                # no separate MLP: the Mamba block is the whole layer
    vocab=50280,
    pattern=("ssm",),
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk=256, conv_width=4),
    norm="rmsnorm",
    pos="none",
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2405.21060",
)
