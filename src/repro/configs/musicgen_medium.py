"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L, d_model=1536, 24 heads (MHA kv=24), d_ff=6144 (GELU), vocab=2048.
Backbone only per the assignment: the EnCodec/T5 frontend is a stub; ``input_specs``
provides precomputed conditioning embeddings consumed by cross-attention.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    pattern=("attn",),
    act="gelu",
    gated_mlp=False,
    norm="layernorm",
    pos="sinusoidal",
    cross_attn=True,
    cond_len=64,
    source="arXiv:2306.05284",
)
