"""internvl2-2b — InternViT + InternLM2 [arXiv:2404.16821].

Backbone (InternLM2-1.8B): 24L, d_model=2048, 16 heads (GQA kv=8, head_dim=128),
d_ff=8192 (SwiGLU), vocab=92553. The InternViT frontend is a stub per the assignment:
``input_specs`` supplies 256 precomputed patch embeddings prepended to the text tokens.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    pattern=("attn",),
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1000000.0,
    prefix_embeds=256,
    # measured (§Perf cell B): GSPMD re-gathers this arch's dh-sharded cache every
    # decode step; the seq-sharded layout cuts decode collective bytes 60x
    cache_seq_shard=True,
    source="arXiv:2404.16821",
)
