"""gemma2-2b — local+global alternating attention, logit softcapping [arXiv:2408.00118].

26L, d_model=2304, 8 heads (GQA kv=4, head_dim=256), d_ff=9216 (GeGLU), vocab=256000.
Even layers use a 4096-token sliding window; odd layers are global. Attention logits
soft-capped at 50, final logits at 30; query scale 1/sqrt(256); sqrt(d) embed scaling;
post-block RMSNorms.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    pattern=("local", "attn"),
    window=4096,
    act="gelu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=1.0 / 16.0,      # 1/sqrt(256)
    scale_embedding=True,
    post_block_norm=True,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
