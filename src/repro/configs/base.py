"""Architecture + run configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``. The full configs are
exercised only through the dry-run (ShapeDtypeStruct lowering); smoke tests use
``cfg.reduced()`` which shrinks every dimension while preserving the family
(block pattern, attention kind, MoE-ness, ...).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Any


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router: str = "softmax_topk"  # or "sigmoid_bias" (DeepSeek aux-loss-free)
    routed_scaling: float = 1.0
    aux_loss_coef: float = 0.0
    # first `start_layer` layers use a dense FFN instead of MoE (DeepSeek-V3: 3)
    start_layer: int = 0
    n_expert_pad: int = 0        # experts padded (masked out) for even sharding
    chunk_tokens: int = 4096     # per-device dispatch chunk (bounds a2a buffers)

    @property
    def n_experts_padded(self) -> int:
        return self.n_experts + self.n_expert_pad


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block parameters [arXiv:2405.21060]."""
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    conv_width: int = 4
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """Griffin RG-LRU recurrent block parameters [arXiv:2402.19427]."""
    lru_width: int = 0           # 0 -> d_model
    conv_width: int = 4
    c: float = 8.0               # a_t = a^(c*r_t)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention [arXiv:2412.19437]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # block pattern: repeating unit of layer kinds; len(pattern) divides into n_layers
    # kinds: "attn" (full), "local" (windowed), "ssm", "rglru"
    pattern: tuple[str, ...] = ("attn",)
    window: int = 0                   # local attention window
    act: str = "silu"
    gated_mlp: bool = True
    norm: str = "rmsnorm"             # rmsnorm | layernorm | layernorm_np (non-parametric)
    rope_theta: float = 10000.0
    pos: str = "rope"                 # rope | sinusoidal | none
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    query_scale: float = 0.0          # 0 -> 1/sqrt(head_dim)
    tie_embeddings: bool = False
    post_block_norm: bool = False     # gemma2-style post-norms
    scale_embedding: bool = False     # gemma-style sqrt(d) embed scale
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    mla: MLAConfig | None = None
    cross_attn: bool = False          # musicgen: cross-attend to conditioning stub
    cond_len: int = 64                # conditioning sequence length (stub)
    prefix_embeds: int = 0            # internvl2: precomputed patch embeds prepended
    mtp: bool = False                 # DeepSeek multi-token-prediction aux block
    cache_seq_shard: bool = False     # decode KV cache sharded on seq (see §Perf B)
    dtype: str = "bfloat16"
    # substrate defaults (overridable per run)
    optimizer: str = "adamw"
    remat: str = "full"               # none | full | dots
    sub_quadratic: bool = False       # eligible for long_500k
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def dh(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, 256)

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer kind list of length n_layers (pattern repeated + truncated)."""
        reps = math.ceil(self.n_layers / len(self.pattern))
        return tuple((self.pattern * reps)[: self.n_layers])

    def n_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def n_params_active(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        unit = len(self.pattern)
        n_layers = max(unit, 2 if unit == 1 else unit)
        kw: dict[str, Any] = dict(
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            window=min(self.window, 32) if self.window else 0,
            cond_len=8 if self.cross_attn else self.cond_len,
            prefix_embeds=4 if self.prefix_embeds else 0,
        )
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe, n_experts=8, top_k=2, d_ff_expert=32,
                d_ff_shared=32 if self.moe.n_shared else 0,
                start_layer=min(self.moe.start_layer, 1),
                n_expert_pad=0, chunk_tokens=64,
            )
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16, chunk=16)
        if self.rglru is not None:
            kw["rglru"] = replace(self.rglru, lru_width=64)
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
            kw["head_dim"] = 0
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_is_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run cell; reason if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 0.5M-token context is quadratic and the "
                       "KV cache alone exceeds sane HBM; run only for SSM/hybrid archs "
                       "(see DESIGN.md §5)")
    return True, ""


@dataclass(frozen=True)
class RunConfig:
    """Training/serving hyper-parameters independent of the architecture."""
    arch: str = "tinyllama-1.1b"
    shape: str = "train_4k"
    # paper-technique knobs (the "stock Hadoop" baseline turns all of these off)
    bucketed_updates: bool = True        # JNI-buffering analogue
    bucket_bytes: int = 1 << 28
    compress_grads: bool = False         # LZO analogue (int8 + error feedback)
    compress_moe_a2a: bool = False       # LZO on the shuffle
    hierarchical_sync: bool = True       # shared-memory-vs-TCP analogue
    donate_state: bool = True            # direct-I/O analogue
    pod_param_mode: str = "sharded"      # replicated (pure DP over pods) | sharded
    remat: str = "full"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    steps: int = 200
    microbatch: int = 0                  # 0 = no grad accumulation
    seed: int = 0
    attention_impl: str = "masked"       # masked | blocked_causal (triangular schedule)
    attn_chunk: int = 1024

    def attention_impl_for(self, seq_len: int) -> str:
        """Pick the attention inner loop for a sequence length.

        ``masked`` materializes S^2 scores, so it is only safe for short sequences;
        both long-seq paths bound memory at [.., S, chunk] per step.
        """
        if self.attention_impl == "blocked_causal" and seq_len > self.attn_chunk:
            return "blocked_causal"
        if seq_len > self.attn_chunk:
            return "chunked"
        return "masked"

    def paper_faithful(self) -> "RunConfig":
        """The 'stock' baseline: every optimization off (paper's starting point)."""
        return dataclasses.replace(
            self, bucketed_updates=False, compress_grads=False,
            compress_moe_a2a=False, hierarchical_sync=False, donate_state=False,
        )
