"""olmo-1b — non-parametric LayerNorm [arXiv:2402.00838].

16L, d_model=2048, 16 heads (MHA: kv=16), d_ff=8192 (SwiGLU), vocab=50304.
OLMo's LayerNorm carries no learnable scale/bias (norm="layernorm_np").
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    pattern=("attn",),
    act="silu",
    gated_mlp=True,
    norm="layernorm_np",
    rope_theta=10000.0,
    tie_embeddings=True,
    source="arXiv:2402.00838",
)
