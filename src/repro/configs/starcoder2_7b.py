"""starcoder2-7b — GQA + RoPE [arXiv:2402.19173].

32L, d_model=4608, 36 heads (GQA kv=4, head_dim=128), d_ff=18432 (plain GELU MLP),
vocab=49152, LayerNorm.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab=49152,
    pattern=("attn",),
    act="gelu",
    gated_mlp=False,
    norm="layernorm",
    rope_theta=100000.0,
    source="arXiv:2402.19173",
)
