"""tinyllama-1.1b — llama2-arch small [arXiv:2401.02385].

22L, d_model=2048, 32 heads (GQA kv=4), d_ff=5632 (SwiGLU), vocab=32000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    pattern=("attn",),
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    source="arXiv:2401.02385",
)
