"""recurrentgemma-2b — RG-LRU + local attention, 2 recurrent : 1 attention [arXiv:2402.19427].

26L, d_model=2560, 10 heads (MQA kv=1, head_dim=256), d_ff=7680 (GeGLU), vocab=256000,
lru_width=2560, local window 2048. Pattern unit (rglru, rglru, local).
"""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,               # 8 full (r,r,a) units + (r,r)
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    act="gelu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, c=8.0),
    scale_embedding=True,
    tie_embeddings=True,
    sub_quadratic=True,        # recurrence + bounded-window attention
    source="arXiv:2402.19427",
)
