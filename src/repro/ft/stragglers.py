"""Straggler detection + mitigation policy.

At thousand-node scale some hosts run slow (thermal, faulty HBM, noisy neighbors).
The monitor tracks per-host step-time EMAs; hosts slower than ``k x median`` are
flagged. Mitigation ladder (in order):

1. rebalance: shift microbatch quota away from the straggler (keeps the mesh),
2. exclude: drop the host and trigger an elastic remesh via checkpoint restore.

Pure policy logic — deterministic and unit-testable with synthetic timings; the
launcher wires it to real step times.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    ema: float = 0.8
    threshold: float = 1.5          # k x median -> straggler
    patience: int = 3               # consecutive flags before action
    rebalance_cap: float = 0.5      # max fraction of quota that can be shifted
    exclude_after: int = 10         # flags before recommending exclusion


class StragglerMonitor:
    def __init__(self, hosts: list[int], cfg: StragglerConfig | None = None):
        self.cfg = cfg or StragglerConfig()
        self.hosts = list(hosts)
        self.ema: dict[int, float] = {}
        self.flags: dict[int, int] = defaultdict(int)
        self.quota: dict[int, float] = {h: 1.0 for h in hosts}

    def record(self, host: int, step_time: float):
        prev = self.ema.get(host)
        a = self.cfg.ema
        self.ema[host] = step_time if prev is None else a * prev + (1 - a) * step_time

    def stragglers(self) -> list[int]:
        if len(self.ema) < 2:
            return []
        med = float(np.median(list(self.ema.values())))
        out = []
        for h, t in self.ema.items():
            if t > self.cfg.threshold * med:
                self.flags[h] += 1
                if self.flags[h] >= self.cfg.patience:
                    out.append(h)
            else:
                self.flags[h] = 0
        return out

    def propose(self) -> dict:
        """-> {"action": "none"|"rebalance"|"exclude", ...}."""
        s = self.stragglers()
        if not s:
            return {"action": "none"}
        med = float(np.median(list(self.ema.values())))
        worst = max(s, key=lambda h: self.ema[h])
        if self.flags[worst] >= self.cfg.exclude_after:
            return {"action": "exclude", "host": worst,
                    "surviving": [h for h in self.hosts if h != worst]}
        # shift quota proportionally to the slowdown, capped
        slow = self.ema[worst] / med
        shift = min(1.0 - 1.0 / slow, self.cfg.rebalance_cap)
        new_quota = dict(self.quota)
        taken = new_quota[worst] * shift
        new_quota[worst] -= taken
        others = [h for h in self.hosts if h != worst]
        for h in others:
            new_quota[h] += taken / len(others)
        self.quota = new_quota
        return {"action": "rebalance", "host": worst, "quota": new_quota}
