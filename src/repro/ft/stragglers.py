"""Straggler detection + mitigation policy.

At thousand-node scale some hosts run slow (thermal, faulty HBM, noisy neighbors).
The monitor tracks per-host step-time EMAs; hosts slower than ``k x median`` are
flagged. Mitigation ladder (in order):

1. rebalance: shift microbatch quota away from the straggler (keeps the mesh),
2. exclude: drop the host and trigger an elastic remesh via checkpoint restore.

``SpeculativePolicy`` is the MapReduce-side analogue — Hadoop's speculative
execution: the streaming executor's ``LanePool``
(``mapreduce/executor.py``) feeds it per-split wall times; a running split
whose elapsed time exceeds ``slowdown x`` the median completed-split wall is
a re-dispatch candidate, slowest first, each split cloned at most
``max_clones`` times — and the executor now *executes* the verdict (clone
onto a free lane, first finisher wins).

Both monitors share one ``WallTracker`` core — the per-key latest wall
(optionally EMA-smoothed), the completed-wall stream, and the
``k x median`` slowness test — so lane, host, and batch monitors cannot
drift apart in how they define "slow".

Pure policy logic — deterministic and unit-testable with synthetic timings; the
launcher and lane pool wire it to real step/split times.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np


class WallTracker:
    """Shared wall-time state for every straggler-shaped monitor.

    Tracks two views of the same observations: ``by_key`` — the latest wall
    per key, EMA-smoothed when ``ema`` is set (host monitors smooth; split
    monitors don't, a split completes once) — and ``completed``, the raw
    ordered stream of observed walls (what split-median speculation judges
    against). The ``k x median`` slowness test lives here so "slow" means
    the same thing to every consumer.
    """

    def __init__(self, ema: float | None = None):
        self.ema = ema
        self.by_key: dict[int, float] = {}
        self.completed: list[float] = []

    def observe(self, key: int, wall_s: float):
        wall_s = float(wall_s)
        self.completed.append(wall_s)
        prev = self.by_key.get(key)
        a = self.ema
        self.by_key[key] = (wall_s if prev is None or a is None
                            else a * prev + (1 - a) * wall_s)

    def median_by_key(self) -> float:
        return float(np.median(list(self.by_key.values())))

    def median_completed(self) -> float:
        return float(np.median(self.completed))

    @staticmethod
    def is_slow(elapsed_s: float, median_s: float, threshold: float) -> bool:
        return elapsed_s > threshold * median_s


@dataclasses.dataclass
class StragglerConfig:
    ema: float = 0.8
    threshold: float = 1.5          # k x median -> straggler
    patience: int = 3               # consecutive flags before action
    rebalance_cap: float = 0.5      # max fraction of quota that can be shifted
    exclude_after: int = 10         # flags before recommending exclusion


class StragglerMonitor:
    def __init__(self, hosts: list[int], cfg: StragglerConfig | None = None):
        self.cfg = cfg or StragglerConfig()
        self.hosts = list(hosts)
        self.track = WallTracker(ema=self.cfg.ema)
        self.ema = self.track.by_key    # legacy name: per-host smoothed walls
        self.flags: dict[int, int] = defaultdict(int)
        self.quota: dict[int, float] = {h: 1.0 for h in hosts}

    def record(self, host: int, step_time: float):
        self.track.observe(host, step_time)

    def stragglers(self) -> list[int]:
        if len(self.track.by_key) < 2:
            return []
        med = self.track.median_by_key()
        out = []
        for h, t in self.track.by_key.items():
            if self.track.is_slow(t, med, self.cfg.threshold):
                self.flags[h] += 1
                if self.flags[h] >= self.cfg.patience:
                    out.append(h)
            else:
                self.flags[h] = 0
        return out

    def propose(self) -> dict:
        """-> {"action": "none"|"rebalance"|"exclude", ...}."""
        s = self.stragglers()
        if not s:
            return {"action": "none"}
        med = self.track.median_by_key()
        worst = max(s, key=lambda h: self.track.by_key[h])
        if self.flags[worst] >= self.cfg.exclude_after:
            return {"action": "exclude", "host": worst,
                    "surviving": [h for h in self.hosts if h != worst]}
        # shift quota proportionally to the slowdown, capped
        slow = self.track.by_key[worst] / med
        shift = min(1.0 - 1.0 / slow, self.cfg.rebalance_cap)
        new_quota = dict(self.quota)
        taken = new_quota[worst] * shift
        new_quota[worst] -= taken
        others = [h for h in self.hosts if h != worst]
        for h in others:
            new_quota[h] += taken / len(others)
        self.quota = new_quota
        return {"action": "rebalance", "host": worst, "quota": new_quota}


@dataclasses.dataclass
class SpeculativeConfig:
    slowdown: float = 1.5       # elapsed > k x median completed wall -> slow
    min_finished: int = 3       # completed splits needed before judging
    max_clones: int = 1         # re-dispatches allowed per split


class SpeculativePolicy:
    """Hadoop's speculative re-execution as pure, clock-free policy.

    The caller reports ``finished(split, wall_s)`` for completed splits and
    ``running(split, elapsed_s)`` for in-flight ones (elapsed measured by
    the caller — no wall clock in here, so decisions replay exactly in
    tests). ``propose()`` picks the slowest running split whose elapsed
    already exceeds ``slowdown x`` the median completed wall — by then a
    fresh re-execution on a healthy worker is expected to beat the original
    — unless that split has been cloned ``max_clones`` times. The winner of
    original-vs-clone is whichever calls ``finished`` first; duplicates are
    idempotent because split results are deterministic.

    ``mapreduce.executor.LanePool`` executes the verdict: the slow split is
    cloned onto a free lane, the first finisher's result commits, and the
    loser is cancelled between stages and its buffers dropped."""

    def __init__(self, cfg: SpeculativeConfig | None = None):
        self.cfg = cfg or SpeculativeConfig()
        self.track = WallTracker()      # completed-wall stream, no smoothing
        self._running: dict[int, float] = {}
        self.clones: dict[int, int] = defaultdict(int)

    @property
    def walls(self) -> list[float]:
        return self.track.completed

    def running(self, split: int, elapsed_s: float):
        self._running[split] = float(elapsed_s)

    def finished(self, split: int, wall_s: float):
        self._running.pop(split, None)
        self.track.observe(split, wall_s)

    def record(self, split: int, wall_s: float):
        """StragglerMonitor-shaped alias, so the streaming executor can feed
        either monitor through one ``straggler_monitor=`` hook."""
        self.finished(split, wall_s)

    def propose(self) -> dict:
        """-> {"action": "none"} | {"action": "speculate", "split": s,
        "elapsed_s": t, "expected_s": median} (slowest eligible split)."""
        if len(self.walls) < self.cfg.min_finished or not self._running:
            return {"action": "none"}
        med = self.track.median_completed()
        cands = [(t, s) for s, t in self._running.items()
                 if self.track.is_slow(t, med, self.cfg.slowdown)
                 and self.clones[s] < self.cfg.max_clones]
        if not cands:
            return {"action": "none"}
        t, s = max(cands)
        self.clones[s] += 1
        return {"action": "speculate", "split": s, "elapsed_s": t,
                "expected_s": med}
