"""Straggler detection + mitigation policy.

At thousand-node scale some hosts run slow (thermal, faulty HBM, noisy neighbors).
The monitor tracks per-host step-time EMAs; hosts slower than ``k x median`` are
flagged. Mitigation ladder (in order):

1. rebalance: shift microbatch quota away from the straggler (keeps the mesh),
2. exclude: drop the host and trigger an elastic remesh via checkpoint restore.

``SpeculativePolicy`` is the MapReduce-side analogue — Hadoop's speculative
execution as pure policy: the streaming executor
(``mapreduce/executor.py``) feeds it (and/or a ``StragglerMonitor``) per-split
wall times; a running split whose elapsed time exceeds ``slowdown x`` the
median completed-split wall is a re-dispatch candidate, slowest first, each
split cloned at most ``max_clones`` times.

Pure policy logic — deterministic and unit-testable with synthetic timings; the
launcher wires it to real step/split times.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    ema: float = 0.8
    threshold: float = 1.5          # k x median -> straggler
    patience: int = 3               # consecutive flags before action
    rebalance_cap: float = 0.5      # max fraction of quota that can be shifted
    exclude_after: int = 10         # flags before recommending exclusion


class StragglerMonitor:
    def __init__(self, hosts: list[int], cfg: StragglerConfig | None = None):
        self.cfg = cfg or StragglerConfig()
        self.hosts = list(hosts)
        self.ema: dict[int, float] = {}
        self.flags: dict[int, int] = defaultdict(int)
        self.quota: dict[int, float] = {h: 1.0 for h in hosts}

    def record(self, host: int, step_time: float):
        prev = self.ema.get(host)
        a = self.cfg.ema
        self.ema[host] = step_time if prev is None else a * prev + (1 - a) * step_time

    def stragglers(self) -> list[int]:
        if len(self.ema) < 2:
            return []
        med = float(np.median(list(self.ema.values())))
        out = []
        for h, t in self.ema.items():
            if t > self.cfg.threshold * med:
                self.flags[h] += 1
                if self.flags[h] >= self.cfg.patience:
                    out.append(h)
            else:
                self.flags[h] = 0
        return out

    def propose(self) -> dict:
        """-> {"action": "none"|"rebalance"|"exclude", ...}."""
        s = self.stragglers()
        if not s:
            return {"action": "none"}
        med = float(np.median(list(self.ema.values())))
        worst = max(s, key=lambda h: self.ema[h])
        if self.flags[worst] >= self.cfg.exclude_after:
            return {"action": "exclude", "host": worst,
                    "surviving": [h for h in self.hosts if h != worst]}
        # shift quota proportionally to the slowdown, capped
        slow = self.ema[worst] / med
        shift = min(1.0 - 1.0 / slow, self.cfg.rebalance_cap)
        new_quota = dict(self.quota)
        taken = new_quota[worst] * shift
        new_quota[worst] -= taken
        others = [h for h in self.hosts if h != worst]
        for h in others:
            new_quota[h] += taken / len(others)
        self.quota = new_quota
        return {"action": "rebalance", "host": worst, "quota": new_quota}


@dataclasses.dataclass
class SpeculativeConfig:
    slowdown: float = 1.5       # elapsed > k x median completed wall -> slow
    min_finished: int = 3       # completed splits needed before judging
    max_clones: int = 1         # re-dispatches allowed per split


class SpeculativePolicy:
    """Hadoop's speculative re-execution as pure, clock-free policy.

    The caller reports ``finished(split, wall_s)`` for completed splits and
    ``running(split, elapsed_s)`` for in-flight ones (elapsed measured by
    the caller — no wall clock in here, so decisions replay exactly in
    tests). ``propose()`` picks the slowest running split whose elapsed
    already exceeds ``slowdown x`` the median completed wall — by then a
    fresh re-execution on a healthy worker is expected to beat the original
    — unless that split has been cloned ``max_clones`` times. The winner of
    original-vs-clone is whichever calls ``finished`` first; duplicates are
    idempotent because split results are deterministic."""

    def __init__(self, cfg: SpeculativeConfig | None = None):
        self.cfg = cfg or SpeculativeConfig()
        self.walls: list[float] = []
        self._running: dict[int, float] = {}
        self.clones: dict[int, int] = defaultdict(int)

    def running(self, split: int, elapsed_s: float):
        self._running[split] = float(elapsed_s)

    def finished(self, split: int, wall_s: float):
        self._running.pop(split, None)
        self.walls.append(float(wall_s))

    def record(self, split: int, wall_s: float):
        """StragglerMonitor-shaped alias, so the streaming executor can feed
        either monitor through one ``straggler_monitor=`` hook."""
        self.finished(split, wall_s)

    def propose(self) -> dict:
        """-> {"action": "none"} | {"action": "speculate", "split": s,
        "elapsed_s": t, "expected_s": median} (slowest eligible split)."""
        if len(self.walls) < self.cfg.min_finished or not self._running:
            return {"action": "none"}
        med = float(np.median(self.walls))
        cands = [(t, s) for s, t in self._running.items()
                 if t > self.cfg.slowdown * med
                 and self.clones[s] < self.cfg.max_clones]
        if not cands:
            return {"action": "none"}
        t, s = max(cands)
        self.clones[s] += 1
        return {"action": "speculate", "split": s, "elapsed_s": t,
                "expected_s": med}
