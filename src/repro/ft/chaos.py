"""Fault injection for the lane scheduler — chaos testing the MapReduce path.

The paper's cluster survives slow and dying Atom nodes through Hadoop's
retry + speculative re-execution; this module injects exactly those faults
into the repro, deterministically, so the recovery machinery can be tested
and benchmarked instead of trusted:

- ``FaultySplitSource`` wraps any ``SplitSource`` and injects, per split
  index, seeded **delays** (a slow disk/NIC on the node that owns the
  block — by default only the first ``delay_calls`` fetches pay it, so a
  speculative clone's re-fetch on a healthy lane is fast and wins; raise
  ``delay_calls`` to make the slowness data-bound so the clone LOSES) and
  **transient fetch errors** (``TransientSplitError`` for the first
  ``faults[k]`` calls, then success — what bounded-backoff retry exists
  for). Delay sleeps poll a cancel event so a cancelled speculation loser
  wakes immediately instead of serving out its injected stall.
- ``LaneChaos`` injects faults at the lane (worker) level: scheduled
  **lane deaths** (``LaneDeath`` on the n-th task a lane starts — the pool
  must shrink and requeue, not hang) and per-lane **delays** (a uniformly
  slow worker, Hadoop's weak node).

Everything is seeded/deterministic and thread-safe; nothing here imports
the executor, so chaos wrappers compose with any consumer of the split
protocol.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.data.pipeline import SplitSource


class TransientSplitError(RuntimeError):
    """A fetch failure that a retry is expected to cure (flaky I/O)."""


class LaneDeath(RuntimeError):
    """A lane (worker) died mid-run; its queued work must be re-dispatched
    onto the surviving lanes."""


def _interruptible_sleep(seconds: float, cancel=None, poll_s: float = 0.02):
    """Sleep ``seconds`` but wake early if ``cancel`` (threading.Event) is
    set. -> True if the sleep was cut short by cancellation."""
    if seconds <= 0:
        return False
    if cancel is None:
        time.sleep(seconds)
        return False
    deadline = time.perf_counter() + seconds
    while not cancel.is_set():
        left = deadline - time.perf_counter()
        if left <= 0:
            return False
        time.sleep(min(poll_s, left))
    return True


class FaultySplitSource(SplitSource):
    """A ``SplitSource`` with per-split injected delays and transient fetch
    errors.

    - ``delays[k] = s``: fetching split ``k`` sleeps ``s`` seconds, for the
      first ``delay_calls.get(k, 1)`` calls only (the straggler is the slow
      node holding the block; a clone re-fetching elsewhere is fast). Set
      ``delay_calls[k]`` large to make every attempt slow (clone loses).
    - ``faults[k] = n``: the first ``n`` calls for split ``k`` raise
      ``TransientSplitError``; call ``n+1`` succeeds — so a retry budget of
      ``n`` wins and ``n-1`` loses, deterministically.
    - ``seed``/``delay_p``/``fault_p``: optionally derive the two maps
      randomly but reproducibly over ``inner.n_splits()`` splits.

    ``split_cancellable(k, cancel)`` is the lane-aware entry point: the
    injected sleep polls ``cancel`` and raises ``CancelledFetch`` when the
    pool cancels the losing attempt mid-stall.
    """

    def __init__(self, inner: SplitSource, *,
                 delays: dict[int, float] | None = None,
                 delay_calls: dict[int, int] | None = None,
                 faults: dict[int, int] | None = None,
                 seed: int | None = None, delay_p: float = 0.0,
                 fault_p: float = 0.0, delay_s: float = 0.05,
                 max_faults: int = 1):
        self.inner = inner
        self.delays = dict(delays or {})
        self.delay_calls = dict(delay_calls or {})
        self.faults = dict(faults or {})
        if seed is not None:
            rng = np.random.default_rng(seed)
            for k in range(inner.n_splits()):
                if delay_p and rng.random() < delay_p:
                    self.delays.setdefault(k, delay_s)
                if fault_p and rng.random() < fault_p:
                    self.faults.setdefault(
                        k, int(rng.integers(1, max_faults + 1)))
        self._lock = threading.Lock()
        self.calls: dict[int, int] = {}          # per-split fetch attempts
        self.injected_delay_s = 0.0              # total stall actually served
        self.injected_faults = 0

    def n_splits(self) -> int:
        return self.inner.n_splits()

    def split(self, k: int):
        return self.split_cancellable(k, None)

    def split_cancellable(self, k: int, cancel):
        with self._lock:
            call = self.calls.get(k, 0)
            self.calls[k] = call + 1
            fault = call < self.faults.get(k, 0)
            stall = (self.delays.get(k, 0.0)
                     if call < self.delay_calls.get(k, 1) else 0.0)
            if fault:
                self.injected_faults += 1
        if fault:
            raise TransientSplitError(
                f"injected transient fetch error for split {k} "
                f"(attempt {call})")
        if stall:
            t0 = time.perf_counter()
            cut = _interruptible_sleep(stall, cancel)
            with self._lock:
                self.injected_delay_s += time.perf_counter() - t0
            if cut:
                raise CancelledFetch(f"split {k} fetch cancelled mid-delay")
        return self.inner.split(k)

    def materialize(self):
        # parity oracle must not pay (or consume) the injected faults
        return self.inner.materialize()


class CancelledFetch(RuntimeError):
    """An injected stall was cancelled by the lane pool (speculation loser)."""


class LaneChaos:
    """Lane-level fault schedule for ``LanePool``.

    - ``kills``: iterable of ``(lane_id, nth_task)`` — that lane raises
      ``LaneDeath`` when it STARTS its nth task (0-based), before touching
      it, so the task is safely re-dispatched.
    - ``lane_delay[lane_id] = s``: every task that lane runs first sleeps
      ``s`` seconds (a uniformly slow worker). Interruptible by the task's
      cancel event.
    """

    def __init__(self, *, kills=(), lane_delay: dict[int, float] | None = None):
        self.kills = {(int(lane), int(n)) for lane, n in kills}
        self.lane_delay = dict(lane_delay or {})
        self._lock = threading.Lock()
        self.n_started: dict[int, int] = {}
        self.deaths: list[tuple[int, int]] = []  # (lane, key) actually killed

    def on_task_start(self, lane_id: int, key: int, attempt: int, cancel=None):
        with self._lock:
            nth = self.n_started.get(lane_id, 0)
            self.n_started[lane_id] = nth + 1
            kill = (lane_id, nth) in self.kills
            if kill:
                self.deaths.append((lane_id, key))
        if kill:
            raise LaneDeath(f"injected death of lane {lane_id} "
                            f"at task #{nth} (split {key})")
        stall = self.lane_delay.get(lane_id, 0.0)
        if stall:
            if _interruptible_sleep(stall, cancel):
                raise CancelledFetch(
                    f"lane {lane_id} task for split {key} cancelled mid-delay")
