"""Failure-handling coordinator: heartbeat tracking + restart/elastic decisions.

State machine:  HEALTHY -> DEGRADED (missed heartbeats) -> REMESH (host declared
dead) -> HEALTHY (after elastic restore).  Decisions are pure functions of observed
events so they can be tested deterministically; the launcher executes them
(checkpoint restore onto the surviving mesh via Checkpointer's elastic path).

The same machine now also tracks LANE liveness: ``mapreduce.executor.LanePool``
registers its lanes as "hosts", forwards each lane's last heartbeat into
``heartbeat()`` from the drain loop, and executes ``tick()``'s verdicts —
"remesh" shrinks the pool and requeues the dead lanes' in-flight splits,
"abort" (below ``min_hosts`` survivors) fails the job. One failure-handling
state machine for training hosts, serving batches, and MapReduce lanes.
"""
from __future__ import annotations

import dataclasses
import enum


class State(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    REMESH = "remesh"


@dataclasses.dataclass
class CoordinatorConfig:
    heartbeat_timeout: float = 30.0
    misses_to_degrade: int = 2
    misses_to_dead: int = 5
    min_hosts: int = 1


class Coordinator:
    def __init__(self, hosts: list[int], cfg: CoordinatorConfig | None = None):
        self.cfg = cfg or CoordinatorConfig()
        self.hosts = set(hosts)
        self.last_seen: dict[int, float] = {}
        self.misses: dict[int, int] = {h: 0 for h in hosts}
        self.state = State.HEALTHY
        self.dead: set[int] = set()

    def heartbeat(self, host: int, now: float):
        self.last_seen[host] = now
        self.misses[host] = 0

    def tick(self, now: float) -> dict:
        """Advance the state machine; returns the action the launcher must take."""
        for h in sorted(self.hosts - self.dead):
            seen = self.last_seen.get(h)
            if seen is None or now - seen > self.cfg.heartbeat_timeout:
                self.misses[h] = self.misses.get(h, 0) + 1
        degraded = [h for h in self.hosts - self.dead
                    if self.misses.get(h, 0) >= self.cfg.misses_to_degrade]
        newly_dead = [h for h in self.hosts - self.dead
                      if self.misses.get(h, 0) >= self.cfg.misses_to_dead]
        if newly_dead:
            self.dead.update(newly_dead)
            surviving = sorted(self.hosts - self.dead)
            if len(surviving) < self.cfg.min_hosts:
                self.state = State.REMESH
                return {"action": "abort", "reason": "below min_hosts"}
            self.state = State.REMESH
            return {"action": "remesh", "dead": sorted(self.dead),
                    "surviving": surviving}
        if degraded:
            self.state = State.DEGRADED
            return {"action": "checkpoint_now", "degraded": degraded}
        self.state = State.HEALTHY
        return {"action": "none"}

    def remesh_done(self):
        self.hosts -= self.dead
        self.state = State.HEALTHY

    def alive(self) -> list[int]:
        """Hosts (or lanes) not declared dead, sorted."""
        return sorted(self.hosts - self.dead)
