from repro.ft.stragglers import (SpeculativeConfig, SpeculativePolicy,
                                 StragglerConfig, StragglerMonitor)
from repro.ft.coordinator import Coordinator, CoordinatorConfig, State
