from repro.ft.stragglers import (SpeculativeConfig, SpeculativePolicy,
                                 StragglerConfig, StragglerMonitor,
                                 WallTracker)
from repro.ft.coordinator import Coordinator, CoordinatorConfig, State
from repro.ft.chaos import (CancelledFetch, FaultySplitSource, LaneChaos,
                            LaneDeath, TransientSplitError)
