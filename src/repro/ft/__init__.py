from repro.ft.stragglers import StragglerMonitor, StragglerConfig
from repro.ft.coordinator import Coordinator, CoordinatorConfig, State
