from repro.optim.optimizers import opt_init, opt_update, apply_updates
from repro.optim.schedule import warmup_cosine
