"""Optimizers: AdamW, SGD+momentum, Adafactor — per-tensor or bucketed.

Bucketed mode (core/buckets.py) is the paper's output-buffering analogue: the whole
gradient pytree is flattened into a few large fp32 buffers and the optimizer update is
a handful of fused elementwise ops instead of hundreds of tiny ones. Adafactor keeps
per-tensor states (factored second moments need the tensor shape) and is used for the
671B config where Adam-class state does not fit HBM.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import buckets as bk


# ---------------------------------------------------------------------------
# Per-tensor kernels (operate on one array; mapped or fused over buckets)
# ---------------------------------------------------------------------------

def _adamw_update(g, m, v, p, *, lr, b1, b2, eps, wd, step):
    gf = g.astype(jnp.float32)
    m = b1 * m + (1 - b1) * gf
    v = b2 * v + (1 - b2) * jnp.square(gf)
    mh = m / (1 - b1 ** step)
    vh = v / (1 - b2 ** step)
    upd = -lr * (mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32))
    return upd, m, v


def _sgdm_update(g, m, p, *, lr, beta, wd):
    gf = g.astype(jnp.float32) + wd * p.astype(jnp.float32)
    m = beta * m + gf
    return -lr * m, m


def _adafactor_update(g, state, p, *, lr, b2, eps, wd, step):
    gf = g.astype(jnp.float32)
    g2 = jnp.square(gf) + 1e-30
    decay = 1.0 - (step ** -0.8)
    if gf.ndim >= 2:
        vr = decay * state["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
        vc = decay * state["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
        rfac = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
        vhat = rfac[..., None] * vc[..., None, :]
        new = {"vr": vr, "vc": vc}
    else:
        v = decay * state["v"] + (1 - decay) * g2
        vhat = v
        new = {"v": v}
    u = gf / jnp.sqrt(vhat + eps)
    # update clipping (Shazeer & Stern)
    rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
    u = u / jnp.maximum(1.0, rms)
    upd = -lr * (u + wd * p.astype(jnp.float32))
    return upd, new


# ---------------------------------------------------------------------------
# Public optimizer API
# ---------------------------------------------------------------------------

def opt_init(name: str, params, *, bucketed: bool = False,
             bucket_bytes: int = 1 << 28, pad_multiple: int = 1):
    """Returns opt state pytree. For bucketed adamw/sgdm, states are buckets."""
    if name == "adafactor":
        def st(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"per": jax.tree.map(st, params)}
    if bucketed:
        plan = bk.make_plan(params, bucket_bytes, pad_multiple)
        zeros = bk.zeros_like_buckets(plan)
        if name == "adamw":
            return {"m": zeros, "v": bk.zeros_like_buckets(plan)}
        if name == "sgdm":
            return {"m": zeros}
        raise ValueError(name)
    if name == "adamw":
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}
    if name == "sgdm":
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)}
    raise ValueError(name)


def opt_update(kind: str, opt_state, grads, params, *, lr, wd: float = 0.1,
               step, plan: bk.BucketPlan | None = None,
               grads_are_buckets: bool = False):
    """-> (updates_tree_or_buckets, new_opt_state).

    If ``plan`` is given and the optimizer is bucketed, grads may be passed either as
    a tree (flattened here) or as ready buckets (``grads_are_buckets``) — the latter is
    how the explicit-sync path avoids a second flatten.
    """
    stepf = step.astype(jnp.float32) + 1.0
    if kind == "adamw_b":
        gb = grads if grads_are_buckets else bk.flatten(plan, grads)
        pb = bk.flatten(plan, params)
        outs = [ _adamw_update(g, m, v, p, lr=lr, b1=0.9, b2=0.95, eps=1e-8,
                               wd=wd, step=stepf)
                 for g, m, v, p in zip(gb, opt_state["m"], opt_state["v"], pb)]
        upd_b = [o[0] for o in outs]
        new = {"m": [o[1] for o in outs], "v": [o[2] for o in outs]}
        return upd_b, new
    if kind == "sgdm_b":
        gb = grads if grads_are_buckets else bk.flatten(plan, grads)
        pb = bk.flatten(plan, params)
        outs = [_sgdm_update(g, m, p, lr=lr, beta=0.9, wd=wd)
                for g, m, p in zip(gb, opt_state["m"], pb)]
        return [o[0] for o in outs], {"m": [o[1] for o in outs]}
    if kind == "adamw":
        flat_g, td = jax.tree.flatten(grads)
        flat_m = jax.tree.flatten(opt_state["m"])[0]
        flat_v = jax.tree.flatten(opt_state["v"])[0]
        flat_p = jax.tree.flatten(params)[0]
        outs = [_adamw_update(g, m, v, p, lr=lr, b1=0.9, b2=0.95, eps=1e-8,
                              wd=wd, step=stepf)
                for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        upd = jax.tree.unflatten(td, [o[0] for o in outs])
        new = {"m": jax.tree.unflatten(td, [o[1] for o in outs]),
               "v": jax.tree.unflatten(td, [o[2] for o in outs])}
        return upd, new
    if kind == "sgdm":
        flat_g, td = jax.tree.flatten(grads)
        flat_m = jax.tree.flatten(opt_state["m"])[0]
        flat_p = jax.tree.flatten(params)[0]
        outs = [_sgdm_update(g, m, p, lr=lr, beta=0.9, wd=wd)
                for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (jax.tree.unflatten(td, [o[0] for o in outs]),
                {"m": jax.tree.unflatten(td, [o[1] for o in outs])})
    if kind == "adafactor":
        flat_g, td = jax.tree.flatten(grads)
        flat_s = jax.tree.flatten(opt_state["per"],
                                  is_leaf=lambda x: isinstance(x, dict) and
                                  ("vr" in x or "v" in x))[0]
        flat_p = jax.tree.flatten(params)[0]
        outs = [_adafactor_update(g, s, p, lr=lr, b2=0.999, eps=1e-30, wd=wd,
                                  step=stepf)
                for g, s, p in zip(flat_g, flat_s, flat_p)]
        upd = jax.tree.unflatten(td, [o[0] for o in outs])
        tds = jax.tree.structure(opt_state["per"],
                                 is_leaf=lambda x: isinstance(x, dict) and
                                 ("vr" in x or "v" in x))
        new = {"per": jax.tree.unflatten(tds, [o[1] for o in outs])}
        return upd, new
    raise ValueError(kind)


def apply_updates(params, updates, *, plan: bk.BucketPlan | None = None):
    """params + updates (updates may be buckets)."""
    if isinstance(updates, list):
        upd_tree = bk.unflatten(plan, updates)
    else:
        upd_tree = updates
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) +
                                      u.astype(jnp.float32)).astype(p.dtype),
                        params, upd_tree)
