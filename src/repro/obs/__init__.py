"""Observability: structured tracing, energy metering, and service metrics.

Three small, dependency-light layers threaded through the MapReduce runtime
(the executor, the per-split engines, the spill tier, and the query
service):

- ``obs.trace``: a thread-safe ``Tracer`` with nestable spans (map /
  combine / shuffle / reduce / fetch-wait / spill-write / lane-exec /
  retry / clone-race / service-batch) on a monotonic clock, exportable as
  Chrome trace-event JSON (load it in Perfetto / chrome://tracing) plus a
  text summary. Disabled by default via a no-op ``NullTracer``.
- ``obs.energy``: an ``EnergyMeter`` protocol — ``RaplMeter`` (powercap
  sysfs counter deltas, wraparound-safe), optional ``NvmlMeter``, and a
  ``ModeledMeter`` driven by ``PowerProfile`` watts (Atom-class host vs
  blade-class device) — attributing joules to ``StageStats`` by
  active-wall share. Disabled by default via ``NullMeter``.
- ``obs.metrics``: a counters/gauges/histograms registry with JSON/text
  export, fed live by ``serving.mr_service`` (qps, queue depth, p50/p99).
"""
from repro.obs.energy import (ATOM_HOST, BLADE_DEVICE, EnergyMeter,
                              ModeledMeter, NullMeter, NvmlMeter,
                              PowerProfile, RaplMeter, get_meter, pick_meter,
                              set_meter, use_meter)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_metrics)
from repro.obs.trace import (NullTracer, Tracer, get_tracer, set_tracer,
                             use_tracer)

__all__ = [
    "ATOM_HOST", "BLADE_DEVICE", "Counter", "EnergyMeter", "Gauge",
    "Histogram", "MetricsRegistry", "ModeledMeter", "NullMeter",
    "NullTracer", "NvmlMeter", "PowerProfile", "RaplMeter", "Tracer",
    "get_meter", "get_metrics", "get_tracer", "pick_meter", "set_meter",
    "set_tracer", "use_meter", "use_tracer",
]
