"""Energy metering: joules attributed to MapReduce stages.

The paper's headline is energy, not wall time: Amdahl-balanced blades do
7.7x (data-intensive) / 3.4x (compute-intensive) more work per joule
than a conventional cluster. An ``EnergyMeter`` turns one job run into
per-stage joules on its ``StageStats``:

- ``RaplMeter``: reads Intel RAPL counters from the powercap sysfs
  (``/sys/class/powercap/intel-rapl*/energy_uj``) at run boundaries,
  wraparound-safe via ``max_energy_range_uj``. Skipped (``available`` is
  False) when the hierarchy is missing or unreadable.
- ``NvmlMeter``: NVIDIA total-energy counter via pynvml, when importable
  and a device is present.
- ``ModeledMeter``: watts x wall from a ``PowerProfile`` — the fallback
  that always works, and the one ``fig9_energy`` uses so the efficiency
  ratios are reproducible on any machine.

Measured meters (RAPL/NVML) observe one counter delta per run and
attribute it to stages by active-wall share; the modeled meter charges
each stage its profile's class watts directly. Either way the joules
land in the ``StageStats`` energy fields (``energy_j``, per-stage
``*_energy_j``, ``rows_per_joule``), which ``merge_from`` accumulates
like any other per-stage cost.

The ``PowerProfile`` watt split encodes the paper's observation (its
Table 2): on an unbalanced low-power node the CPU pays for I/O — moving
a byte costs as much CPU time as computing on it — while the
Amdahl-balanced blade moves bytes at a fraction of its compute draw.
So the host-engine profile charges I/O stages *above* its compute draw
and the blade-class device profile charges them well below.
"""
from __future__ import annotations

import contextlib
import dataclasses
import glob
import os
import threading
from typing import Any, Dict, Iterator, List, Optional, Tuple

# StageStats wall/energy field pairs, split by resource class. "Compute"
# stages burn ALU; "io" stages move bytes (shuffle wire, split fetch,
# spill disk) — the axis the paper's balance argument turns on.
COMPUTE_STAGES = ("map", "reduce", "combine")
IO_STAGES = ("shuffle", "fetch", "spill")
ALL_STAGES = COMPUTE_STAGES + IO_STAGES


@dataclasses.dataclass(frozen=True)
class PowerProfile:
    """Modeled node watts by stage class.

    ``compute_w`` draws while map/reduce/combine run; ``io_w`` while
    shuffle/fetch/spill run. Profiles describe the *node class an engine
    stands in for*, not this machine: the host (numpy) engine plays the
    paper's unbalanced low-power CPU node, the device engine its
    Amdahl-balanced blade.
    """

    name: str
    compute_w: float
    io_w: float

    def stage_watts(self, stage: str) -> float:
        return self.io_w if stage in IO_STAGES else self.compute_w


# Atom-class node (the paper's D510/N330 boards): ~8 W TDP CPU, ~28 W at
# the wall under load, and I/O *adds* draw (disk + NIC) on top of a CPU
# that is already saturated shovelling the bytes (paper Table 2: network
# I/O alone eats the core).
ATOM_HOST = PowerProfile("atom-host", compute_w=28.0, io_w=33.0)
# Amdahl-balanced blade (Atom + SSD + matched NIC): similar compute draw,
# but bytes move through hardware sized for the CPU, so I/O phases draw
# far below the compute phases.
BLADE_DEVICE = PowerProfile("amdahl-blade", compute_w=24.0, io_w=8.0)


def _charge(stats: Any, stage: str, joules: float) -> None:
    field = f"{stage}_energy_j"
    setattr(stats, field, getattr(stats, field) + joules)
    stats.energy_j += joules


def _stage_walls(stats: Any) -> Dict[str, float]:
    return {s: getattr(stats, f"{s}_wall_s") for s in ALL_STAGES}


class EnergyMeter:
    """Protocol: ``begin()`` returns a token at run start; ``attribute
    (token, stats)`` charges the run's joules onto its StageStats."""

    name = "null"

    @property
    def available(self) -> bool:
        return True

    def begin(self) -> Any:
        return None

    def attribute(self, token: Any, stats: Any) -> None:
        return None


class NullMeter(EnergyMeter):
    """Disabled metering: the default; both calls are no-ops."""


class ModeledMeter(EnergyMeter):
    """Watts x stage wall from a ``PowerProfile`` per engine.

    Deterministic and machine-independent: the meter every bench and CI
    run can use. Picks the profile by ``stats.engine`` ("host" ->
    ``host`` profile, anything else -> ``device``).
    """

    name = "modeled"

    def __init__(self, host: PowerProfile = ATOM_HOST,
                 device: PowerProfile = BLADE_DEVICE):
        self.host = host
        self.device = device

    def profile_for(self, stats: Any) -> PowerProfile:
        return self.host if stats.engine == "host" else self.device

    def attribute(self, token: Any, stats: Any) -> None:
        prof = self.profile_for(stats)
        for stage, wall in _stage_walls(stats).items():
            if wall > 0.0:
                _charge(stats, stage, wall * prof.stage_watts(stage))
        stats.energy_source = f"modeled:{prof.name}"


class _WallShareMeter(EnergyMeter):
    """Shared logic for measured meters: one joule delta per run,
    attributed to stages by their share of the summed active wall."""

    def read_joules(self, token: Any) -> float:
        raise NotImplementedError

    def attribute(self, token: Any, stats: Any) -> None:
        if not self.available or token is None:
            return
        joules = self.read_joules(token)
        walls = _stage_walls(stats)
        total = sum(walls.values())
        if joules <= 0.0 or total <= 0.0:
            return
        for stage, wall in walls.items():
            if wall > 0.0:
                _charge(stats, stage, joules * wall / total)
        stats.energy_source = self.name


class RaplMeter(_WallShareMeter):
    """Intel RAPL via the powercap sysfs; wraparound-safe deltas.

    Sums the top-level ``intel-rapl:<n>`` package domains. Counters are
    microjoule accumulators that wrap at ``max_energy_range_uj``; a
    negative delta is unwrapped by adding the range. ``available`` is
    False (and ``begin`` returns None) when the hierarchy is missing or
    the counters are unreadable (common unprivileged/container case).
    """

    name = "rapl"

    def __init__(self, root: str = "/sys/class/powercap"):
        self._domains: List[Tuple[str, float]] = []
        for d in sorted(glob.glob(os.path.join(root, "intel-rapl:[0-9]*"))):
            if ":" in os.path.basename(d).replace("intel-rapl:", "", 1):
                continue  # subdomain (core/uncore/dram): avoid double count
            counter = os.path.join(d, "energy_uj")
            try:
                self._read_uj(counter)
                max_uj = float(
                    open(os.path.join(d, "max_energy_range_uj")).read())
            except OSError:
                continue
            self._domains.append((counter, max_uj))

    @staticmethod
    def _read_uj(path: str) -> float:
        with open(path) as f:
            return float(f.read().strip())

    @property
    def available(self) -> bool:
        return bool(self._domains)

    def begin(self) -> Optional[List[float]]:
        if not self.available:
            return None
        try:
            return [self._read_uj(p) for p, _ in self._domains]
        except OSError:
            return None

    def read_joules(self, token: List[float]) -> float:
        total_uj = 0.0
        try:
            for (path, max_uj), start in zip(self._domains, token):
                delta = self._read_uj(path) - start
                if delta < 0.0:  # counter wrapped during the run
                    delta += max_uj
                total_uj += delta
        except OSError:
            return 0.0
        return total_uj * 1e-6


class NvmlMeter(_WallShareMeter):
    """NVIDIA device energy via pynvml's total-energy counter (mJ).

    ``available`` is False when pynvml is absent, init fails, or no
    device exposes the counter — the common non-GPU case.
    """

    name = "nvml"

    def __init__(self, index: int = 0):
        self._handle = None
        try:
            import pynvml
            pynvml.nvmlInit()
            handle = pynvml.nvmlDeviceGetHandleByIndex(index)
            pynvml.nvmlDeviceGetTotalEnergyConsumption(handle)
            self._pynvml = pynvml
            self._handle = handle
        except Exception:
            self._handle = None

    @property
    def available(self) -> bool:
        return self._handle is not None

    def _read_mj(self) -> float:
        return float(self._pynvml.nvmlDeviceGetTotalEnergyConsumption(
            self._handle))

    def begin(self) -> Optional[float]:
        if not self.available:
            return None
        try:
            return self._read_mj()
        except Exception:
            return None

    def read_joules(self, token: float) -> float:
        try:
            return max(self._read_mj() - token, 0.0) * 1e-3
        except Exception:
            return 0.0


def pick_meter(prefer: str = "auto") -> EnergyMeter:
    """Resolve a meter by name: "rapl" / "nvml" / "modeled" / "null", or
    "auto" = first *available* of RAPL, NVML, else the modeled fallback
    (measured-where-readable, modeled-watts-otherwise — the comparison
    methodology of the SBC/ARM64 Hadoop studies)."""
    if prefer == "null":
        return NullMeter()
    if prefer == "modeled":
        return ModeledMeter()
    if prefer == "rapl":
        return RaplMeter()
    if prefer == "nvml":
        return NvmlMeter()
    for meter in (RaplMeter(), NvmlMeter()):
        if meter.available:
            return meter
    return ModeledMeter()


_CURRENT: EnergyMeter = NullMeter()
_CURRENT_LOCK = threading.Lock()


def get_meter() -> EnergyMeter:
    """Current meter (``NullMeter`` unless one was installed)."""
    return _CURRENT


def set_meter(meter: EnergyMeter) -> EnergyMeter:
    """Install ``meter`` globally; returns the previous meter."""
    global _CURRENT
    with _CURRENT_LOCK:
        prev, _CURRENT = _CURRENT, meter
    return prev


@contextlib.contextmanager
def use_meter(meter: EnergyMeter) -> Iterator[EnergyMeter]:
    """Scoped ``set_meter``: restores the previous meter on exit."""
    prev = set_meter(meter)
    try:
        yield meter
    finally:
        set_meter(prev)
