"""Structured tracing: nestable spans on a monotonic clock.

A ``Tracer`` records complete spans (Chrome trace-event ``ph: "X"``) and
instant marks (``ph: "i"``) from any thread. Spans carry the recording
thread id plus whatever correlation ids the caller attaches (lane /
split / request / attempt ...), either per-span or ambiently via the
``ids()`` context so nested spans inherit them — the lane worker opens
``ids(lane=..., split=...)`` once and every stage span recorded inside
the task picks the ids up.

Export targets:

- ``chrome_trace()`` / ``export_json()`` / ``save(path)``: the Chrome
  trace-event JSON object format (``{"traceEvents": [...]}``), loadable
  in Perfetto or chrome://tracing. Timestamps are microseconds relative
  to tracer construction.
- ``summary()``: a per-span-name text table (count / total / mean / max).

The module-level current tracer defaults to ``NullTracer`` whose
``span()`` / ``ids()`` return a shared reentrant no-op context manager,
so instrumented hot paths cost one attribute lookup and one method call
when tracing is off.

Spans close in a ``finally`` block, so an exception thrown mid-stage (a
chaos-killed lane, a cancelled clone) still closes every opened span —
``open_spans`` returning 0 after a crashy run is a tested invariant.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional


class _NullCtx:
    """Reentrant no-op context manager shared by every NullTracer call."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class NullTracer:
    """Disabled tracer: every call is a no-op returning shared objects."""

    enabled = False

    def span(self, name: str, cat: str = "stage", **ids) -> _NullCtx:
        return _NULL_CTX

    def ids(self, **ids) -> _NullCtx:
        return _NULL_CTX

    def record(self, name: str, t0_s: float, t1_s: float,
               cat: str = "stage", **ids) -> None:
        return None

    def instant(self, name: str, cat: str = "mark", **ids) -> None:
        return None

    @property
    def events(self) -> tuple:
        return ()

    @property
    def open_spans(self) -> int:
        return 0


class Tracer:
    """Thread-safe span recorder with ambient correlation ids."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        self.events: List[Dict[str, Any]] = []
        self._tls = threading.local()
        self._opened = 0
        self._closed = 0

    # -- ambient correlation ids -------------------------------------
    def _id_stack(self) -> List[Dict[str, Any]]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _ambient(self) -> Dict[str, Any]:
        merged: Dict[str, Any] = {}
        for frame in self._id_stack():
            merged.update(frame)
        return merged

    @contextlib.contextmanager
    def ids(self, **ids) -> Iterator[None]:
        """Attach correlation ids to every span opened in this thread."""
        stack = self._id_stack()
        stack.append(ids)
        try:
            yield
        finally:
            stack.pop()

    # -- recording ---------------------------------------------------
    def _append(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self.events.append(ev)

    def _event(self, name: str, cat: str, ph: str, t0_s: float,
               dur_s: Optional[float], ids: Dict[str, Any]) -> Dict[str, Any]:
        args = self._ambient()
        args.update(ids)
        ev: Dict[str, Any] = {
            "name": name,
            "cat": cat,
            "ph": ph,
            "ts": (t0_s - self._t0) * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": args,
        }
        if dur_s is not None:
            ev["dur"] = dur_s * 1e6
        if ph == "i":
            ev["s"] = "t"  # instant scope: thread
        return ev

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "stage", **ids) -> Iterator[None]:
        """Record a complete span around the with-body (closes in finally)."""
        t0 = self._clock()
        with self._lock:
            self._opened += 1
        try:
            yield
        finally:
            t1 = self._clock()
            ev = self._event(name, cat, "X", t0, t1 - t0, ids)
            with self._lock:
                self._closed += 1
                self.events.append(ev)

    def record(self, name: str, t0_s: float, t1_s: float,
               cat: str = "stage", **ids) -> None:
        """Record a span retroactively from caller-measured timestamps.

        ``t0_s``/``t1_s`` must come from the tracer's clock (default
        ``time.perf_counter``) — used for waits measured before the span
        is known to matter, e.g. the prefetch fetch-wait.
        """
        self._append(self._event(name, cat, "X", t0_s,
                                 max(t1_s - t0_s, 0.0), ids))

    def instant(self, name: str, cat: str = "mark", **ids) -> None:
        self._append(self._event(name, cat, "i", self._clock(), None, ids))

    def now(self) -> float:
        return self._clock()

    @property
    def open_spans(self) -> int:
        with self._lock:
            return self._opened - self._closed

    # -- export ------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        with self._lock:
            events = list(self.events)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_json(self) -> str:
        return json.dumps(self.chrome_trace())

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.export_json())
        return path

    def summary(self) -> str:
        """Per-name text table: count, total/mean/max duration in ms."""
        with self._lock:
            events = list(self.events)
        agg: Dict[str, List[float]] = {}
        marks: Dict[str, int] = {}
        for ev in events:
            if ev["ph"] == "X":
                agg.setdefault(ev["name"], []).append(ev["dur"])
            else:
                marks[ev["name"]] = marks.get(ev["name"], 0) + 1
        lines = [f"{'span':<16} {'count':>6} {'total_ms':>10} "
                 f"{'mean_ms':>9} {'max_ms':>9}"]
        for name in sorted(agg, key=lambda n: -sum(agg[n])):
            durs = agg[name]
            lines.append(
                f"{name:<16} {len(durs):>6} {sum(durs) / 1e3:>10.3f} "
                f"{sum(durs) / len(durs) / 1e3:>9.3f} "
                f"{max(durs) / 1e3:>9.3f}")
        for name in sorted(marks):
            lines.append(f"{name:<16} {marks[name]:>6} {'(instant)':>10}")
        return "\n".join(lines)


_CURRENT: Any = NullTracer()
_CURRENT_LOCK = threading.Lock()


def get_tracer() -> Any:
    """Current tracer (a ``Tracer`` or the default ``NullTracer``)."""
    return _CURRENT


def set_tracer(tracer: Any) -> Any:
    """Install ``tracer`` globally; returns the previous tracer."""
    global _CURRENT
    with _CURRENT_LOCK:
        prev, _CURRENT = _CURRENT, tracer
    return prev


@contextlib.contextmanager
def use_tracer(tracer: Any) -> Iterator[Any]:
    """Scoped ``set_tracer``: restores the previous tracer on exit."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)
