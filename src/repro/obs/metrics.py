"""Service metrics: a small counters/gauges/histograms registry.

The MR query service feeds one of these live per service instance
(requests/batches counters, queue-depth and qps gauges, latency and
queue-wait histograms), and anything else in the runtime can hang
numbers on the shared default registry. Exports as JSON (``to_dict`` /
``to_json``) or a Prometheus-flavoured text page (``render_text``).

Histograms keep a bounded sample window (drop-oldest) so a long-lived
service can't grow without bound; percentiles are computed over the
window, which for a service means "recent" — the operationally useful
reading of p50/p99.
"""
from __future__ import annotations

import collections
import json
import threading
from typing import Deque, Dict, Optional


class Counter:
    """Monotonic count (requests served, batches run, retries)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time level (queue depth, qps, resident bytes)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Sampled distribution with percentiles over a bounded window."""

    def __init__(self, name: str, max_samples: int = 4096):
        self.name = name
        self._lock = threading.Lock()
        self._window: Deque[float] = collections.deque(maxlen=max_samples)
        self._count = 0
        self._sum = 0.0

    def observe(self, v: float) -> None:
        with self._lock:
            self._window.append(float(v))
            self._count += 1
            self._sum += v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> float:
        """q in [0, 100] over the retained window (0.0 when empty)."""
        with self._lock:
            data = sorted(self._window)
        if not data:
            return 0.0
        # nearest-rank on the sorted window; exact at the ends
        idx = min(int(round(q / 100.0 * (len(data) - 1))), len(data) - 1)
        return data[max(idx, 0)]

    def snapshot(self) -> dict:
        with self._lock:
            data = sorted(self._window)
            count, total = self._count, self._sum
        if not data:
            return {"count": count, "sum": total, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p99": 0.0}
        def rank(q):
            return data[min(int(round(q / 100.0 * (len(data) - 1))),
                            len(data) - 1)]
        return {"count": count, "sum": total,
                "mean": sum(data) / len(data),
                "min": data[0], "max": data[-1],
                "p50": rank(50), "p99": rank(99)}


class MetricsRegistry:
    """Named get-or-create home for counters, gauges, and histograms."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            return self._counters.setdefault(name, Counter(name))

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            return self._gauges.setdefault(name, Gauge(name))

    def histogram(self, name: str, max_samples: int = 4096) -> Histogram:
        with self._lock:
            return self._histograms.setdefault(
                name, Histogram(name, max_samples))

    def to_dict(self) -> dict:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {n: g.value for n, g in sorted(gauges.items())},
            "histograms": {n: h.snapshot()
                           for n, h in sorted(histograms.items())},
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    def render_text(self) -> str:
        """Prometheus-flavoured exposition: one ``name value`` per line,
        histograms as ``_count`` / ``_sum`` / ``{quantile=...}``."""
        d = self.to_dict()
        lines = []
        for name, v in d["counters"].items():
            lines.append(f"{name}_total {v:g}")
        for name, v in d["gauges"].items():
            lines.append(f"{name} {v:g}")
        for name, snap in d["histograms"].items():
            lines.append(f"{name}_count {snap['count']:g}")
            lines.append(f"{name}_sum {snap['sum']:g}")
            for q in ("p50", "p99"):
                lines.append(
                    f'{name}{{quantile="{q}"}} {snap[q]:g}')
        return "\n".join(lines)


_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def get_metrics() -> MetricsRegistry:
    """Process-wide default registry (created on first use)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = MetricsRegistry()
        return _DEFAULT
