"""Deterministic sharded data pipeline + catalog split sources.

Token sources (LM side):
- ``SyntheticTokens``: stateless, hash-based tokens — any (step, position) is
  reproducible on any host without coordination (important for elastic restarts:
  a rescaled job replays the exact same global batch sequence).
- ``MemmapTokens``: packed binary token file via np.memmap (the 'direct I/O' spirit:
  no per-example deserialization, reads go straight from page cache to the array).

``Pipeline`` yields *host-local* slices of the global batch given
(host_id, n_hosts), with a background prefetch thread (depth-bounded queue);
it is a context manager, so the thread can never leak past a ``with`` block.

Split sources (MapReduce side): a ``SplitSource`` is the HDFS-block analogue
— a finite sequence of catalog splits that the streaming executor
(``mapreduce/executor.py``) pulls one at a time, so the full catalog never
has to exist in device memory at once. ``ArraySplits`` chunks an in-memory
array (the one-split case is how ``run_job`` delegates to the executor),
``MemmapCatalogSplits`` reads row chunks of a packed float32 file,
``SyntheticCatalogSplits`` generates sky-catalog chunks deterministically
per split, and ``TokenBlockSplits`` adapts the token sources above into
wordcount-shaped ``[rows, 1]`` splits.

Both consumers share one ``Prefetcher``: a depth-bounded background producer
thread that reports, per item, how long the producer spent building it and
how long the consumer was actually blocked waiting — the split between
*hidden* and *exposed* I/O that the executor's ``overlap_hidden_s``
accounting is built on.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterator

import numpy as np


class SyntheticTokens:
    """tokens[i, j] = mix64(seed, i, j) % vocab — O(1) random access."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = np.uint64(seed)

    def block(self, row0: int, rows: int, cols: int) -> np.ndarray:
        i = (np.arange(row0, row0 + rows, dtype=np.uint64)[:, None] *
             np.uint64(0x9E3779B97F4A7C15))
        j = (np.arange(cols, dtype=np.uint64)[None, :] *
             np.uint64(0xBF58476D1CE4E5B9))
        x = i ^ j ^ (self.seed * np.uint64(0x94D049BB133111EB))
        x ^= x >> np.uint64(31)
        x *= np.uint64(0xD6E8FEB86659FD93)
        x ^= x >> np.uint64(27)
        return (x % np.uint64(self.vocab)).astype(np.int32)


class MemmapTokens:
    """Packed int32 token file of shape [n_rows, seq_len]."""

    def __init__(self, path: str, seq_len: int):
        self.arr = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.n_rows = self.arr.shape[0] // seq_len

    def block(self, row0: int, rows: int, cols: int) -> np.ndarray:
        assert cols == self.seq_len
        out = np.empty((rows, cols), np.int32)
        # contiguous slice reads; the loop only runs when the range wraps
        # around the end of the file (once per full pass)
        got, r = 0, row0 % self.n_rows
        while got < rows:
            take = min(rows - got, self.n_rows - r)
            out[got:got + take] = self.arr[r * cols:(r + take) * cols
                                           ].reshape(take, cols)
            got += take
            r = 0
        return out

    @staticmethod
    def write(path: str, tokens: np.ndarray):
        np.asarray(tokens, np.int32).tofile(path)


class Prefetcher:
    """Depth-bounded background producer (the shared prefetch-thread pattern
    behind ``Pipeline`` and the streaming executor's double buffer).

    ``produce(k)`` is called on a daemon thread for k = start, start+1, ...
    (stopping after ``n`` items when ``n`` is given) and results queue up to
    ``depth`` deep. ``get()`` blocks for the next item and returns
    ``(k, item, wait_s, prep_s)``: ``prep_s`` is how long the producer spent
    building the item, ``wait_s`` how long the *consumer* was blocked — so
    ``prep_s - wait_s`` of I/O was hidden under the consumer's own work.
    Returns ``None`` once the source is exhausted. Context manager: the
    thread is stopped (and joined) on exit, success or failure.

    Terminal state is LATCHED: once the exhaustion sentinel or a producer
    exception has surfaced, every subsequent ``get()`` re-surfaces it
    (returns ``None`` again / re-raises the same exception) instead of
    blocking forever on an empty queue with a dead worker. A consumer
    blocked in ``get()`` wakes with ``None`` when ``stop()`` is called.

    ``stop(drain=True)`` is the producer-side counterpart for writers whose
    produced items must not be lost (the spill writer): the worker finishes
    its in-flight ``produce`` and hands the item off instead of dropping it
    when it races a full queue, and every undelivered record is returned.
    """

    def __init__(self, produce: Callable[[int], object], *, depth: int = 2,
                 start: int = 0, n: int | None = None):
        self._produce = produce
        self._q: queue.Queue = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._drain = threading.Event()
        self._start_k = start
        self._n = n
        self._thread: threading.Thread | None = None
        self._busy_k: int | None = None    # index currently inside produce()
        self._terminal = None              # latched: _EXHAUSTED or exception

    _EXHAUSTED = object()

    def start(self) -> "Prefetcher":
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def _put(self, rec) -> bool:
        # cancel semantics: stop() abandons the in-flight item (the drain
        # path instead empties the queue until this hand-off succeeds)
        while not self._stop.is_set():
            try:
                self._q.put(rec, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        k = self._start_k
        while not (self._stop.is_set() or self._drain.is_set()):
            if self._n is not None and k >= self._start_k + self._n:
                self._put(None)
                return
            t0 = time.perf_counter()
            self._busy_k = k
            try:
                item = self._produce(k)
            except BaseException as e:         # surface in the consumer
                self._put(e)
                return
            finally:
                self._busy_k = None
            self._put((k, item, time.perf_counter() - t0))
            k += 1

    def get(self):
        if self._terminal is not None:         # latched terminal state
            if self._terminal is self._EXHAUSTED:
                return None
            raise self._terminal
        if self._thread is None:
            self.start()
        t0 = time.perf_counter()
        while True:
            try:
                rec = self._q.get(timeout=0.05)
                break
            except queue.Empty:
                if self._stop.is_set():        # stop() wakes blocked consumers
                    rec = None
                    break
        wait = time.perf_counter() - t0
        if rec is None:
            self._terminal = self._EXHAUSTED
            return None
        if isinstance(rec, BaseException):
            self._terminal = rec
            raise rec
        k, item, prep = rec
        return k, item, wait, prep

    def stop(self, timeout: float = 2.0, drain: bool = False):
        """Stop and join the producer thread. A failed join used to pass
        silently — a worker wedged inside ``produce(k)`` would leak past the
        ``with`` block and hold its buffers forever; now it raises, naming
        the stuck fetch so the I/O that wedged is identifiable.

        ``drain=True`` (the spill writer's shutdown path): instead of
        abandoning the worker's in-flight item when it races a full queue,
        let the current ``produce`` finish and hand off, consume every
        undelivered record ourselves, and return them — nothing the
        producer finished is ever dropped on the floor. Returns the drained
        record list (``None``/exception records included, for inspection);
        plain ``stop()`` returns ``None`` and keeps cancel semantics."""
        drained = None
        if drain and self._thread is not None:
            drained = []
            self._drain.set()
            deadline = time.perf_counter() + timeout
            while (self._thread.is_alive()
                   and time.perf_counter() < deadline):
                try:
                    drained.append(self._q.get(timeout=0.02))
                except queue.Empty:
                    pass
            while True:                         # leftovers after worker exit
                try:
                    drained.append(self._q.get_nowait())
                except queue.Empty:
                    break
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                k = self._busy_k
                where = (f"inside produce({k})" if k is not None
                         else "blocked handing off an item")
                raise RuntimeError(
                    f"Prefetcher worker thread leaked: still {where} "
                    f"{timeout}s after stop() — the fetch for "
                    f"{'item ' + str(k) if k is not None else 'the queue'} "
                    f"is stuck and its buffers cannot be reclaimed")
            self._thread = None
        return drained

    def __enter__(self) -> "Prefetcher":
        return self.start()

    def __exit__(self, *exc):
        self.stop()


# ---------------------------------------------------------------------------
# SplitSource: HDFS-block-analog catalog splits for the streaming executor
# ---------------------------------------------------------------------------

class SplitSource:
    """A finite, ordered sequence of catalog splits (each a ``[rows, d]`` or
    ``[rows]`` numpy array). The streamed dataset is *defined* as the row
    concatenation of its splits; the streaming executor pulls splits one at
    a time (prefetched), so only one split plus the accumulated partials
    need exist in memory. ``n_splits`` must be >= 1 (an empty dataset is one
    empty split)."""

    def n_splits(self) -> int:
        raise NotImplementedError

    def split(self, k: int) -> np.ndarray:
        raise NotImplementedError

    def materialize(self) -> np.ndarray:
        """The whole dataset at once (oracle/parity runs — defeats the point
        of streaming for anything big)."""
        return np.concatenate([np.atleast_1d(self.split(k))
                               for k in range(self.n_splits())], axis=0)


class ArraySplits(SplitSource):
    """An in-memory array cut at explicit row ``boundaries`` (or into
    ``n_splits`` near-equal chunks). ``ArraySplits(x)`` — one split — is the
    degenerate source ``run_job`` uses to delegate to the executor."""

    def __init__(self, items, n_splits: int = 1,
                 boundaries: "list[int] | None" = None):
        self.items = np.asarray(items)
        n = len(self.items)
        if boundaries is None:
            n_splits = max(1, min(int(n_splits), max(n, 1)))
            step = -(-max(n, 1) // n_splits)
            boundaries = list(range(step, n, step))[:n_splits - 1]
        bounds = [0, *sorted(int(b) for b in boundaries), n]
        assert all(0 <= b <= n for b in bounds), (bounds, n)
        self._bounds = bounds

    def n_splits(self) -> int:
        return len(self._bounds) - 1

    def split(self, k: int) -> np.ndarray:
        return self.items[self._bounds[k]:self._bounds[k + 1]]


class MemmapCatalogSplits(SplitSource):
    """Row chunks of a packed float32 ``[n_rows, d]`` catalog file — the
    out-of-core source: each ``split`` reads one chunk through the page
    cache; nothing ever holds the whole catalog."""

    def __init__(self, path: str, d: int, rows_per_split: int):
        import os
        size = os.path.getsize(path)
        self.d = int(d)
        rem = size % (self.d * 4)
        if rem:
            raise ValueError(
                f"catalog file {path!r} is {size} bytes, not a multiple of "
                f"d*4 = {self.d * 4} ({rem} trailing bytes) — truncated or "
                f"corrupt; refusing to silently read a smaller catalog")
        self.arr = (np.zeros(0, np.float32)       # mmap rejects empty files
                    if size == 0
                    else np.memmap(path, dtype=np.float32, mode="r"))
        self.n_rows = self.arr.shape[0] // self.d
        self.rows_per_split = int(rows_per_split)
        assert self.rows_per_split >= 1

    def n_splits(self) -> int:
        return max(1, -(-self.n_rows // self.rows_per_split))

    def split(self, k: int) -> np.ndarray:
        lo = k * self.rows_per_split
        hi = min(lo + self.rows_per_split, self.n_rows)
        return np.array(self.arr[lo * self.d:hi * self.d]
                        ).reshape(hi - lo, self.d)

    @staticmethod
    def write(path: str, rows: np.ndarray):
        np.asarray(rows, np.float32).tofile(path)


class SyntheticCatalogSplits(SplitSource):
    """Deterministic synthetic sky-catalog splits: split ``k`` is
    ``sky.make_catalog(rows_k, seed=mix(seed, k))``, so any split is
    regenerable independently (no catalog file, no coordination) and the
    streamed catalog is the concatenation of the per-split chunks."""

    def __init__(self, n_rows: int, rows_per_split: int, seed: int = 0):
        self.n_rows = int(n_rows)
        self.rows_per_split = int(rows_per_split)
        self.seed = int(seed)
        assert self.rows_per_split >= 1

    def n_splits(self) -> int:
        return max(1, -(-self.n_rows // self.rows_per_split))

    def split(self, k: int) -> np.ndarray:
        from repro.data import sky
        lo = k * self.rows_per_split
        rows = min(self.rows_per_split, self.n_rows - lo)
        return sky.make_catalog(max(rows, 0),
                                seed=(self.seed * 1_000_003 + k) & 0x7FFFFFFF)


class TokenBlockSplits(SplitSource):
    """Adapts a token source (``SyntheticTokens``/``MemmapTokens``) into
    wordcount-shaped splits: split ``k`` is rows
    ``[k*rows_per_split, (k+1)*rows_per_split)`` of the token matrix,
    flattened to ``[rows*seq_len, 1]`` float32 — the streaming executor's
    input schema."""

    def __init__(self, source, seq_len: int, rows_per_split: int,
                 n_splits: int, start_row: int = 0):
        self.source = source
        self.seq_len = int(seq_len)
        self.rows_per_split = int(rows_per_split)
        self._n = int(n_splits)
        self.start_row = int(start_row)
        assert self._n >= 1 and self.rows_per_split >= 1

    def n_splits(self) -> int:
        return self._n

    def split(self, k: int) -> np.ndarray:
        block = self.source.block(self.start_row + k * self.rows_per_split,
                                  self.rows_per_split, self.seq_len)
        return np.asarray(block, np.float32).reshape(-1, 1)


class SpilledStreamSplits(SplitSource):
    """Reads spilled wire-dtype shuffle segments back as partition-range
    records — the read side of the external shuffle tier. Wraps anything
    with the ``SpillStore`` read interface (``n_ranges``, ``read_range``);
    "split" ``z`` is partition range ``z``.

    Protocol deviation, on purpose: ``split(z)`` returns the *merged range
    record dict* produced by ``SpillStore.read_range`` (host wire arrays +
    ``lo``/``hi`` partition bounds), not a raw ``[n, d]`` float32 catalog
    chunk — the segments hold post-map encoded streams, and decoding them
    back to rows would defeat the codec. Consumers are the streamed-reduce
    path in the executor, which feeds each record straight to
    ``shuffle_reduce_device_streamed``; ``materialize()`` is unsupported
    for the same reason.
    """

    def __init__(self, store):
        self.store = store

    def n_splits(self) -> int:
        return int(self.store.n_ranges)

    def split(self, z: int):
        return self.store.read_range(z)

    def materialize(self):
        raise TypeError(
            "SpilledStreamSplits yields encoded range records, not catalog "
            "rows; there is no meaningful row-matrix materialization")


# ---------------------------------------------------------------------------
# LM batch pipeline
# ---------------------------------------------------------------------------

@dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    host_id: int = 0
    n_hosts: int = 1
    prefetch: int = 2
    start_step: int = 0


class Pipeline:
    """Host-local batch stream with background prefetch. Context manager:
    ``with Pipeline(src, cfg) as pipe: ...`` starts the prefetch thread on
    entry and always stops it on exit (tests can't leak the thread)."""

    def __init__(self, source, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.source = source
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self._pf: Prefetcher | None = None

    def _row0(self, step: int) -> int:
        return (step * self.cfg.global_batch +
                self.cfg.host_id * self.local_batch)

    def batch_at(self, step: int) -> np.ndarray:
        """Deterministic random access (used for elastic replay + tests)."""
        return self.source.block(self._row0(step), self.local_batch,
                                 self.cfg.seq_len)

    def start(self):
        if self._pf is None:
            self._pf = Prefetcher(self.batch_at, depth=self.cfg.prefetch,
                                  start=self.cfg.start_step).start()
        return self

    def stop(self):
        if self._pf is not None:
            self._pf.stop()
            self._pf = None

    def __enter__(self) -> "Pipeline":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    def __iter__(self) -> Iterator[tuple[int, np.ndarray]]:
        self.start()
        while True:
            rec = self._pf.get()
            if rec is None:                     # unbounded source: no end
                return
            step, batch, _, _ = rec
            yield step, batch
