"""Deterministic sharded data pipeline.

Two sources:
- ``SyntheticTokens``: stateless, hash-based tokens — any (step, position) is
  reproducible on any host without coordination (important for elastic restarts:
  a rescaled job replays the exact same global batch sequence).
- ``MemmapTokens``: packed binary token file via np.memmap (the 'direct I/O' spirit:
  no per-example deserialization, reads go straight from page cache to the array).

The pipeline yields *host-local* slices of the global batch given (host_id, n_hosts),
with a background prefetch thread (depth-bounded queue).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


class SyntheticTokens:
    """tokens[i, j] = mix64(seed, i, j) % vocab — O(1) random access."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = np.uint64(seed)

    def block(self, row0: int, rows: int, cols: int) -> np.ndarray:
        i = (np.arange(row0, row0 + rows, dtype=np.uint64)[:, None] *
             np.uint64(0x9E3779B97F4A7C15))
        j = (np.arange(cols, dtype=np.uint64)[None, :] *
             np.uint64(0xBF58476D1CE4E5B9))
        x = i ^ j ^ (self.seed * np.uint64(0x94D049BB133111EB))
        x ^= x >> np.uint64(31)
        x *= np.uint64(0xD6E8FEB86659FD93)
        x ^= x >> np.uint64(27)
        return (x % np.uint64(self.vocab)).astype(np.int32)


class MemmapTokens:
    """Packed int32 token file of shape [n_rows, seq_len]."""

    def __init__(self, path: str, seq_len: int):
        self.arr = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.n_rows = self.arr.shape[0] // seq_len

    def block(self, row0: int, rows: int, cols: int) -> np.ndarray:
        assert cols == self.seq_len
        idx = (np.arange(row0, row0 + rows) % self.n_rows)
        out = np.empty((rows, cols), np.int32)
        for k, r in enumerate(idx):          # rows may wrap; keep simple
            out[k] = self.arr[r * cols:(r + 1) * cols]
        return out

    @staticmethod
    def write(path: str, tokens: np.ndarray):
        np.asarray(tokens, np.int32).tofile(path)


@dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    host_id: int = 0
    n_hosts: int = 1
    prefetch: int = 2
    start_step: int = 0


class Pipeline:
    def __init__(self, source, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.source = source
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self._q: queue.Queue = queue.Queue(maxsize=max(cfg.prefetch, 1))
        self._stop = threading.Event()
        self._step = cfg.start_step
        self._thread: threading.Thread | None = None

    def _row0(self, step: int) -> int:
        return (step * self.cfg.global_batch +
                self.cfg.host_id * self.local_batch)

    def batch_at(self, step: int) -> np.ndarray:
        """Deterministic random access (used for elastic replay + tests)."""
        return self.source.block(self._row0(step), self.local_batch,
                                 self.cfg.seq_len)

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            b = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self):
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

    def __iter__(self) -> Iterator[tuple[int, np.ndarray]]:
        if self._thread is None:
            self.start()
        while True:
            yield self._q.get()
