"""Synthetic astronomy catalogs (the paper's input data, generated).

Points uniform on the unit sphere; the Zones algorithm [Gray et al., MSR-TR-2006-52]
partitions by declination zones of height h (radians). Distances are angular:
theta(a, b) = arccos(a . b); neighbors: theta <= radius.
"""
from __future__ import annotations

import numpy as np

ARCSEC = np.pi / (180.0 * 3600.0)


def make_catalog(n: int, seed: int = 0) -> np.ndarray:
    """-> unit vectors [n, 3] float32, uniform on the sphere."""
    rng = np.random.default_rng(seed)
    z = rng.uniform(-1.0, 1.0, n)
    phi = rng.uniform(0.0, 2 * np.pi, n)
    r = np.sqrt(np.maximum(1.0 - z * z, 0.0))
    return np.stack([r * np.cos(phi), r * np.sin(phi), z],
                    axis=1).astype(np.float32)


def dec_of(xyz: np.ndarray) -> np.ndarray:
    return np.arcsin(np.clip(xyz[:, 2], -1.0, 1.0))


def zone_of(xyz: np.ndarray, zone_height: float) -> np.ndarray:
    """Zone index per point (declination bands of height `zone_height` rad)."""
    return np.floor((dec_of(xyz) + np.pi / 2) / zone_height).astype(np.int32)


def n_zones(zone_height: float) -> int:
    return int(np.ceil(np.pi / zone_height))


def brute_force_pairs(xyz: np.ndarray, radius_rad: float) -> int:
    """O(n^2) oracle: number of unordered pairs within radius."""
    dots = xyz @ xyz.T
    np.fill_diagonal(dots, -2.0)
    return int(np.sum(dots >= np.cos(radius_rad)) // 2)


def brute_force_hist(xyz: np.ndarray, edges_rad: np.ndarray) -> np.ndarray:
    """Pair-distance histogram oracle (the Neighbor Statistics application)."""
    dots = np.clip(xyz @ xyz.T, -1.0, 1.0)
    iu = np.triu_indices(len(xyz), k=1)
    theta = np.arccos(dots[iu])
    h, _ = np.histogram(theta, bins=edges_rad)
    return h
