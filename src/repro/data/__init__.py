from repro.data.pipeline import (
    ArraySplits, MemmapCatalogSplits, MemmapTokens, Pipeline, PipelineConfig,
    Prefetcher, SpilledStreamSplits, SplitSource, SyntheticCatalogSplits,
    SyntheticTokens, TokenBlockSplits,
)
from repro.data import sky
