from repro.data.pipeline import (
    Pipeline, PipelineConfig, SyntheticTokens, MemmapTokens,
)
from repro.data import sky
