from repro.data.pipeline import (
    ArraySplits, MemmapCatalogSplits, MemmapTokens, Pipeline, PipelineConfig,
    Prefetcher, SplitSource, SyntheticCatalogSplits, SyntheticTokens,
    TokenBlockSplits,
)
from repro.data import sky
