"""Shared model primitives: norms, positions, activations, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Norms (computed in fp32, cast back)
# ---------------------------------------------------------------------------

def rmsnorm(x, scale=None, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def layernorm(x, scale=None, bias=None, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(kind: str, x, params: dict | None):
    """kind: rmsnorm | layernorm | layernorm_np; params holds 'scale'/'bias' if any."""
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"] if params else None)
    if kind == "layernorm":
        return layernorm(x, params["scale"] if params else None,
                         params.get("bias") if params else None)
    if kind == "layernorm_np":          # OLMo: non-parametric
        return layernorm(x, None, None)
    raise ValueError(kind)


def norm_schema(kind: str, d: int):
    from repro.parallel.sharding import ParamDef
    if kind == "rmsnorm":
        return {"scale": ParamDef((d,), (None,), init="zeros")}
    if kind == "layernorm":
        return {"scale": ParamDef((d,), (None,), init="ones"),
                "bias": ParamDef((d,), (None,), init="zeros")}
    if kind == "layernorm_np":
        return {}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Rotary embedding. x: [..., S, H, Dh] (or [..., S, Dh]); positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / dh))
    ang = positions[..., None].astype(jnp.float32) * freq          # [..., S, half]
    if x.ndim == ang.ndim + 2:                                      # head dim present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d: int, dtype=jnp.bfloat16):
    """[..., S] -> [..., S, d] sinusoidal embedding (MusicGen-style)."""
    half = d // 2
    freq = np.exp(-np.log(10000.0) * np.arange(half, dtype=np.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def activate(kind: str, x):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def softcap(x, cap: float):
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def cross_entropy(logits, labels, *, vocab_real: int, z_loss: float = 1e-4,
                  ignore_index: int = -1):
    """CE over a padded vocab; labels==ignore_index are masked out.

    logits: [..., V_pad] (bf16 ok), labels: [...] int32.
    """
    vpad = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if vpad > vocab_real:
        neg = jnp.full((vpad - vocab_real,), -1e9, jnp.float32)
        mask = jnp.concatenate([jnp.zeros((vocab_real,), jnp.float32), neg])
        lf = lf + mask
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    safe_labels = jnp.clip(labels, 0, vpad - 1)
    picked = jnp.take_along_axis(lf, safe_labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    valid = (labels != ignore_index)
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / denom
