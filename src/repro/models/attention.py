"""Attention: GQA/MQA/MHA, sliding-window, cross-attention, and DeepSeek MLA.

Three interchangeable inner loops (``impl``):

- ``masked``          full scores + additive mask. Fine for short sequences.
- ``chunked``         lax.scan over KV chunks with online softmax (flash-style in pure
                      XLA): bounded memory, still computes masked-out blocks (2x causal
                      FLOP waste — this is the paper-faithful baseline).
- ``blocked_causal``  static triangular block schedule: only (q-block, kv-block) pairs
                      that intersect the causal/window mask are computed. Removes the
                      masked-FLOP waste; the §Perf hillclimb quantifies it.

On TPU the Pallas flash kernel (kernels/flash_attention) replaces the inner loop via
ops.py; the dry-run and CPU tests use these pure-JAX paths (identical FLOP/byte
semantics for roofline purposes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.common import rope, softcap
from repro.parallel.sharding import ParamDef, axis_size, shard_act

NEG_INF = -2.0e9


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------

def attn_schema(cfg: ArchConfig, kind: str) -> dict:
    """kind: attn | local | cross."""
    D, H, Kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    if cfg.mla is not None and kind != "cross":
        m = cfg.mla
        dq = m.nope_head_dim + m.rope_head_dim
        return {
            "w_dq": ParamDef((D, m.q_lora_rank), ("embed", None)),
            "q_norm": ParamDef((m.q_lora_rank,), (None,), init="zeros"),
            "w_uq": ParamDef((m.q_lora_rank, H, dq), (None, "heads", None)),
            "w_dkv": ParamDef((D, m.kv_lora_rank), ("embed", None)),
            "kv_norm": ParamDef((m.kv_lora_rank,), (None,), init="zeros"),
            "w_uk": ParamDef((m.kv_lora_rank, H, m.nope_head_dim), (None, "heads", None)),
            "w_uv": ParamDef((m.kv_lora_rank, H, m.v_head_dim), (None, "heads", None)),
            "w_kr": ParamDef((D, m.rope_head_dim), ("embed", None)),
            "w_o": ParamDef((H, m.v_head_dim, D), ("heads", None, "embed")),
        }
    return {
        "w_q": ParamDef((D, H, dh), ("embed", "heads", None)),
        "w_k": ParamDef((D, Kv, dh), ("embed", "kv_heads", None)),
        "w_v": ParamDef((D, Kv, dh), ("embed", "kv_heads", None)),
        "w_o": ParamDef((H, dh, D), ("heads", None, "embed")),
    }


def cache_def(cfg: ArchConfig, kind: str, batch: int, max_len: int) -> dict:
    """Shape/dims template for a decode cache entry (leaves are ParamDef-like).

    Sharding preference: kv-heads over ``model`` when divisible, else the SEQ dim.
    (Sharding the contraction dim dh makes GSPMD re-gather the whole cache every
    decode step — observed as the dominant collective term; seq-sharding keeps the
    per-step exchange at score size instead of cache size.)
    """
    Kv, dh = cfg.n_kv_heads, cfg.dh
    seq_pref = cfg.cache_seq_shard          # per-arch override (§Perf cell B)
    if cfg.mla is not None and kind != "cross":
        m = cfg.mla
        return {
            "ckv": ParamDef((batch, max_len, m.kv_lora_rank),
                            ("batch", None, "head_dim"), init="zeros"),
            "kr": ParamDef((batch, max_len, m.rope_head_dim),
                           ("batch", None, None), init="zeros"),
        }
    L = min(max_len, cfg.window) if kind == "local" and cfg.window else max_len
    if kind == "cross":
        L = cfg.cond_len
    # preference: kv-heads > head-dim (first-fit with divisibility is resolved by
    # spec_for at sharding time). Seq-sharding is only a win where GSPMD would
    # otherwise re-gather the cache (measured per arch; internvl2 opts in via
    # cache_seq_shard — §Perf cell B): the per-step cache update on a seq-sharded
    # dim costs a replicate-repartition elsewhere.
    if seq_pref:
        dims = ("batch", "seq_model", None, None)
    else:
        dims = ("batch", None, "kv_heads", "head_dim")
    return {
        "k": ParamDef((batch, L, Kv, dh), dims, init="zeros"),
        "v": ParamDef((batch, L, Kv, dh), dims, init="zeros"),
    }


def _qkv_act_dims(cfg: ArchConfig) -> tuple:
    """Prefer head sharding; fall back to sequence sharding (Ulysses-style)."""
    tp = axis_size("model")
    if cfg.n_heads % tp == 0:
        return ("batch", None, "heads", None)
    return ("batch", "seq_model", None, None)


# ---------------------------------------------------------------------------
# Core attend
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: int, k_valid=None):
    """Additive fp32 bias [*, Sq, Sk] from position vectors."""
    rel = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(rel.shape, bool)
    if causal:
        ok &= rel >= 0
    if window:
        ok &= rel < window
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _scores(q, k, scale, cap):
    # q: [B,Sq,Kv,G,dh]  k: [B,Sk,Kv,dh] -> [B,Kv,G,Sq,Sk]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    return softcap(s, cap) if cap else s


def _ctx(p, v):
    # p: [B,Kv,G,Sq,Sk]  v: [B,Sk,Kv,dv] -> [B,Sq,Kv,G,dv]
    return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)


def attend(q, k, v, *, causal: bool, window: int = 0, cap: float = 0.0,
           scale: float | None = None, impl: str = "masked", chunk: int = 1024,
           q_pos=None, k_pos=None, k_valid=None):
    """q: [B,Sq,H,dh], k/v: [B,Sk,Kv,d*]. Returns [B,Sq,H,dv]."""
    B, Sq, H, dh = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(dh)
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if k_pos is None:
        k_pos = jnp.arange(Sk)
    qg = q.reshape(B, Sq, Kv, G, dh)

    if impl == "masked" or Sk <= chunk:
        s = _scores(qg, k, scale, cap)
        s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window,
                           k_valid=k_valid)
        p = jax.nn.softmax(s, axis=-1)
        o = _ctx(p, v)
        return o.reshape(B, Sq, H, dv)

    if impl == "chunked":
        return _attend_chunked(qg, k, v, scale=scale, cap=cap, causal=causal,
                               window=window, chunk=chunk, q_pos=q_pos,
                               k_pos=k_pos, k_valid=k_valid).reshape(B, Sq, H, dv)

    if impl == "blocked_causal":
        return _attend_blocked(qg, k, v, scale=scale, cap=cap, causal=causal,
                               window=window, chunk=chunk).reshape(B, Sq, H, dv)

    raise ValueError(impl)


def _attend_chunked(qg, k, v, *, scale, cap, causal, window, chunk,
                    q_pos, k_pos, k_valid):
    """Online-softmax scan over KV chunks. Computes all blocks (masked baseline)."""
    B, Sq, Kv, G, dh = qg.shape
    Sk, dv = k.shape[1], v.shape[-1]
    nck = -(-Sk // chunk)
    pad = nck * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)
        kv_flag = jnp.pad(k_valid if k_valid is not None
                          else jnp.ones((Sk,), bool), (0, pad))
    else:
        kv_flag = k_valid if k_valid is not None else jnp.ones((Sk,), bool)

    m0 = jnp.full((B, Kv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, Sq, Kv, G, dv), jnp.float32)

    @jax.checkpoint
    def body(carry, i):
        m, l, o = carry
        ks = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(k_pos, i * chunk, chunk, axis=0)
        kf = jax.lax.dynamic_slice_in_dim(kv_flag, i * chunk, chunk, axis=0)
        s = _scores(qg, ks, scale, cap)
        s = s + _mask_bias(q_pos, kp, causal=causal, window=window, k_valid=kf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * jnp.transpose(alpha, (0, 3, 1, 2))[..., None] + \
            _ctx(p, vs.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), jnp.arange(nck))
    l = jnp.maximum(l, 1e-20)
    o = o / jnp.transpose(l, (0, 3, 1, 2))[..., None]
    return o.astype(qg.dtype)


def _attend_blocked(qg, k, v, *, scale, cap, causal, window, chunk):
    """Static triangular block schedule: only blocks intersecting the mask run.

    Assumes q_pos == k_pos == arange(S) (self-attention training/prefill).
    """
    B, Sq, Kv, G, dh = qg.shape
    Sk, dv = k.shape[1], v.shape[-1]
    assert Sq == Sk, "blocked_causal is for self-attention"
    nb = -(-Sq // chunk)
    pad = nb * chunk - Sq
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = nb * chunk

    pairs = []
    for qi in range(nb):
        lo = 0
        if window:
            lo = max(0, (qi * chunk - (window - 1)) // chunk)
        hi = qi if causal else nb - 1
        for kj in range(lo, hi + 1):
            pairs.append((qi, kj))
    qi_arr = jnp.asarray(np.array([p[0] for p in pairs], np.int32))
    kj_arr = jnp.asarray(np.array([p[1] for p in pairs], np.int32))

    m0 = jnp.full((nb, B, Kv, G, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nb, B, Kv, G, chunk), jnp.float32)
    o0 = jnp.zeros((nb, B, chunk, Kv, G, dv), jnp.float32)
    pos = jnp.arange(S)

    @jax.checkpoint
    def body(carry, qikj):
        m, l, o = carry
        qi, kj = qikj
        qs = jax.lax.dynamic_slice_in_dim(qg, qi * chunk, chunk, axis=1)
        ks = jax.lax.dynamic_slice_in_dim(k, qi * 0 + kj * chunk, chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, kj * chunk, chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(pos, qi * chunk, chunk, axis=0)
        kp = jax.lax.dynamic_slice_in_dim(pos, kj * chunk, chunk, axis=0)
        valid_q = qp < Sq
        s = _scores(qs, ks, scale, cap)
        s = s + _mask_bias(qp, kp, causal=causal, window=window,
                           k_valid=kp < Sq)
        mi = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        oi = jax.lax.dynamic_index_in_dim(o, qi, 0, keepdims=False)
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        alpha = jnp.exp(mi - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = li * alpha + jnp.sum(p, axis=-1)
        o_new = oi * jnp.transpose(alpha, (0, 3, 1, 2))[..., None] + \
            _ctx(p, vs.astype(jnp.float32))
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, qi, 0)
        del valid_q
        return (m, l, o), None

    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (qi_arr, kj_arr))
    l = jnp.maximum(l, 1e-20)
    o = o / jnp.transpose(l, (0, 1, 4, 2, 3))[..., None]     # [nb,B,c,Kv,G,dv]
    o = o.reshape(nb, B, chunk, Kv, G, dv)
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, Kv, G, dv)[:, :Sq]
    return o.astype(qg.dtype)


# ---------------------------------------------------------------------------
# Standard (GQA) attention layer: train / prefill / decode
# ---------------------------------------------------------------------------

def gqa_apply(cfg: ArchConfig, p: dict, x, *, kind: str, positions,
              impl: str, chunk: int, cond=None, make_cache: int = 0):
    """x: [B,S,D]. kind: attn|local|cross. Returns (y, cache_entry|None)."""
    B, S, D = x.shape
    dims = _qkv_act_dims(cfg)
    if kind == "cross":
        assert cond is not None
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
        k = jnp.einsum("bsd,dhk->bshk", cond, p["w_k"])
        v = jnp.einsum("bsd,dhk->bshk", cond, p["w_v"])
        q, k, v = shard_act(q, dims), shard_act(k, dims), shard_act(v, dims)
        o = attend(q, k, v, causal=False, impl="masked",
                   scale=cfg.query_scale or None, cap=cfg.attn_logit_softcap)
        y = jnp.einsum("bshk,hkd->bsd", o, p["w_o"])
        cache = {"k": k, "v": v} if make_cache else None
        return y, cache

    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"])
    if cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q, k, v = shard_act(q, dims), shard_act(k, dims), shard_act(v, dims)
    window = cfg.window if kind == "local" else 0
    o = attend(q, k, v, causal=True, window=window, cap=cfg.attn_logit_softcap,
               scale=cfg.query_scale or None, impl=impl, chunk=chunk)
    y = jnp.einsum("bshk,hkd->bsd", o, p["w_o"])

    cache = None
    if make_cache:
        L = make_cache
        if kind == "local" and cfg.window and cfg.window < L:
            L = cfg.window
            k_c, v_c = k[:, -L:], v[:, -L:]
            # ring-buffer layout: slot = pos % window
            roll = (S % L)
            k_c = jnp.roll(k_c, roll, axis=1)
            v_c = jnp.roll(v_c, roll, axis=1)
        else:
            k_c = jnp.pad(k, ((0, 0), (0, L - S), (0, 0), (0, 0)))
            v_c = jnp.pad(v, ((0, 0), (0, L - S), (0, 0), (0, 0)))
        cache = {"k": k_c, "v": v_c}
    return y, cache


def gqa_decode(cfg: ArchConfig, p: dict, x1, cache: dict, pos, *, kind: str):
    """Single-token decode. x1: [B,1,D]; pos: scalar int32 (current index)."""
    B = x1.shape[0]
    if kind == "cross":
        q = jnp.einsum("bsd,dhk->bshk", x1, p["w_q"])
        o = attend(q, cache["k"], cache["v"], causal=False, impl="masked",
                   cap=cfg.attn_logit_softcap, scale=cfg.query_scale or None)
        y = jnp.einsum("bshk,hkd->bsd", o, p["w_o"])
        return y, cache

    q = jnp.einsum("bsd,dhk->bshk", x1, p["w_q"])
    k1 = jnp.einsum("bsd,dhk->bshk", x1, p["w_k"])
    v1 = jnp.einsum("bsd,dhk->bshk", x1, p["w_v"])
    if cfg.pos == "rope":
        pvec = jnp.full((1,), 0, jnp.int32) + pos
        q = rope(q, pvec, cfg.rope_theta)
        k1 = rope(k1, pvec, cfg.rope_theta)

    L = cache["k"].shape[1]
    window = cfg.window if kind == "local" else 0
    slot = pos % L if window else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k1.astype(cache["k"].dtype),
                                            slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v1.astype(cache["v"].dtype),
                                            slot, axis=1)
    idx = jnp.arange(L)
    if window:
        valid = (idx <= pos % L) | (pos >= L)
        # mask only; order irrelevant for windowed softmax (keys carry their rope)
        o = attend(q, k, v, causal=False, impl="masked", k_valid=valid,
                   cap=cfg.attn_logit_softcap, scale=cfg.query_scale or None)
    else:
        valid = idx <= pos
        o = attend(q, k, v, causal=False, impl="masked", k_valid=valid,
                   cap=cfg.attn_logit_softcap, scale=cfg.query_scale or None)
    y = jnp.einsum("bshk,hkd->bsd", o, p["w_o"])
    return y, {"k": k, "v": v}


def gqa_or_mla_apply(cfg: ArchConfig, p: dict, x, *, kind: str, positions,
                     impl: str, chunk: int, make_cache: int = 0):
    if cfg.mla is not None and kind != "cross":
        return mla_apply(cfg, p, x, positions=positions, impl=impl, chunk=chunk,
                         make_cache=make_cache)
    return gqa_apply(cfg, p, x, kind=kind, positions=positions, impl=impl,
                     chunk=chunk, make_cache=make_cache)


def gqa_or_mla_decode(cfg: ArchConfig, p: dict, x1, cache: dict, pos, *, kind: str):
    if cfg.mla is not None and kind != "cross":
        return mla_decode(cfg, p, x1, cache, pos)
    return gqa_decode(cfg, p, x1, cache, pos, kind=kind)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def _mla_qkv(cfg: ArchConfig, p: dict, x, positions):
    from repro.models.common import rmsnorm
    m = cfg.mla
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    ckv = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"])
    kr = rope(jnp.einsum("bsd,dr->bsr", x, p["w_kr"]), positions, cfg.rope_theta)
    return q_nope, q_rope, ckv, kr


def mla_apply(cfg: ArchConfig, p: dict, x, *, positions, impl: str, chunk: int,
              make_cache: int = 0):
    """Training/prefill MLA. Decompressed (naive) form — exact."""
    m = cfg.mla
    B, S, D = x.shape
    q_nope, q_rope, ckv, kr = _mla_qkv(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"])
    vfull = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"])
    H = cfg.n_heads
    k_rope_h = jnp.broadcast_to(kr[:, :, None, :], (B, S, H, m.rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    dims = _qkv_act_dims(cfg)
    q, k, vfull = shard_act(q, dims), shard_act(k, dims), shard_act(vfull, dims)
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    o = attend(q, k, vfull, causal=True, impl=impl, chunk=chunk, scale=scale)
    y = jnp.einsum("bshk,hkd->bsd", o, p["w_o"])
    cache = None
    if make_cache:
        L = make_cache
        cache = {"ckv": jnp.pad(ckv, ((0, 0), (0, L - S), (0, 0))),
                 "kr": jnp.pad(kr, ((0, 0), (0, L - S), (0, 0)))}
    return y, cache


def mla_decode(cfg: ArchConfig, p: dict, x1, cache: dict, pos):
    """Absorbed-matrix decode: score/context directly against the latent cache."""
    m = cfg.mla
    B = x1.shape[0]
    pvec = jnp.zeros((1,), jnp.int32) + pos
    q_nope, q_rope, ckv1, kr1 = _mla_qkv(cfg, p, x1, pvec)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv1.astype(cache["ckv"].dtype), pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["kr"], kr1.astype(cache["kr"].dtype), pos, axis=1)
    # absorb W_uk into q: q_eff [B,1,H,r]
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    s = jnp.einsum("bshr,btr->bhst", q_eff, ckv,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bshk,btk->bhst", q_rope, kr,
                       preferred_element_type=jnp.float32)
    s = s / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    L = ckv.shape[1]
    valid = jnp.arange(L) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhst,btr->bshr", pr.astype(ckv.dtype), ckv)
    o = jnp.einsum("bshr,rhk->bshk", ctx_c, p["w_uv"])
    y = jnp.einsum("bshk,hkd->bsd", o, p["w_o"])
    return y, {"ckv": ckv, "kr": kr}
