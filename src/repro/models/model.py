"""LM wrapper: embedding, stack, head, losses, prefill/decode, input specs."""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.models.common import (apply_norm, cross_entropy, norm_schema,
                                 sinusoidal_pos, softcap)
from repro.models.ffn import ffn_schema
from repro.models.attention import attn_schema
from repro.parallel.sharding import (
    ParamDef, abstract_params, batch_spec, current_mesh, current_rules,
    init_params, sharding_tree, spec_for, shard_act, tree_map_schema)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def model_schema(cfg: ArchConfig) -> tuple[dict, dict]:
    """-> (param schema, router-bias extras schema)."""
    D, Vp = cfg.d_model, cfg.vocab_padded
    stack, biases, _, _ = tfm.stack_schema_for_groups(cfg)
    s: dict = {
        "embed": {"tok": ParamDef((Vp, D), ("vocab", "embed"))},  # ~N(0, 1/sqrt(D))
        "stack": stack,
        "final_norm": norm_schema(cfg.norm, D),
    }
    if not cfg.tie_embeddings:
        s["head"] = {"w": ParamDef((D, Vp), ("embed", "vocab"))}
    if cfg.mtp:
        s["mtp"] = {
            "norm_h": norm_schema(cfg.norm, D),
            "norm_e": norm_schema(cfg.norm, D),
            "proj": ParamDef((2 * D, D), (None, "embed")),
            "layer": tfm.layer_schema(cfg, "attn", "dense"),
            "final_norm": norm_schema(cfg.norm, D),
        }
    return s, biases


def init(cfg: ArchConfig, key):
    ps, bs = model_schema(cfg)
    return init_params(ps, key), init_params(bs, key)


def abstract(cfg: ArchConfig):
    ps, bs = model_schema(cfg)
    return abstract_params(ps), abstract_params(bs)


def param_shardings(cfg: ArchConfig, mesh, rules):
    ps, bs = model_schema(cfg)
    return sharding_tree(ps, mesh, rules), sharding_tree(bs, mesh, rules)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed(cfg: ArchConfig, params, tokens, positions, prefix=None):
    x = params["embed"]["tok"][tokens]                      # gather [B,S,D]
    if cfg.scale_embedding:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_pos(positions, cfg.d_model, x.dtype)
    if prefix is not None:
        Pn = prefix.shape[1]
        x = jnp.concatenate([prefix.astype(x.dtype), x[:, Pn:]], axis=1)
    return shard_act(x, ("batch", None, None))


def _head(cfg: ArchConfig, params, x):
    w = params["head"]["w"] if not cfg.tie_embeddings else \
        params["embed"]["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    logits = shard_act(logits, ("batch", None, "vocab"))
    return softcap(logits, cfg.final_logit_softcap)


def forward(cfg: ArchConfig, rc: RunConfig, params, biases, batch,
            *, make_cache_len: int = 0):
    """batch: tokens [B,S] (+ cond / prefix embeds). Returns (logits, cache, aux)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)
    cond = batch.get("cond")
    prefix = batch.get("prefix")
    x = _embed(cfg, params, tokens, positions, prefix)
    x, cache, aux = tfm.stack_apply(cfg, rc, params["stack"], biases, x,
                                    positions=positions, cond=cond,
                                    make_cache_len=make_cache_len)
    x = apply_norm(cfg.norm, x, params.get("final_norm"))
    logits = _head(cfg, params, x)
    return logits, cache, aux, x


def loss_fn(cfg: ArchConfig, rc: RunConfig, params, biases, batch):
    """Next-token CE (+ MoE aux + optional MTP). Returns (loss, metrics)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    logits, _, aux, h = forward(cfg, rc, params, biases, batch)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((B, 1), -1, tokens.dtype)], axis=1)
    if cfg.prefix_embeds:
        # positions covered by patch embeddings carry no token labels
        pmask = jnp.arange(S) < cfg.prefix_embeds
        labels = jnp.where(pmask[None, :], -1, labels)
    loss = cross_entropy(logits, labels, vocab_real=cfg.vocab)
    metrics = {"ce_loss": loss}

    aux_losses = [v["aux_loss"] for v in jax.tree.leaves(
        aux, is_leaf=lambda n: isinstance(n, dict) and "aux_loss" in n)] \
        if aux else []
    if aux_losses:
        al = sum(jnp.sum(a) for a in aux_losses)
        loss = loss + al
        metrics["moe_aux_loss"] = al

    if cfg.mtp:
        mtp_loss = jax.checkpoint(
            lambda p, t, hh: _mtp_loss(cfg, rc, p, t, hh))(params, tokens, h)
        loss = loss + 0.3 * mtp_loss
        metrics["mtp_loss"] = mtp_loss

    metrics["loss"] = loss
    return loss, (metrics, aux)


def _mtp_loss(cfg: ArchConfig, rc: RunConfig, params, tokens, h):
    """Depth-1 multi-token prediction (predict t+2 from trunk state at t)."""
    m = params["mtp"]
    B, S = tokens.shape
    e = params["embed"]["tok"][tokens[:, 1:]]              # embed of t+1
    e = shard_act(e, ("batch", None, None))
    hh = apply_norm(cfg.norm, h[:, :-1], m["norm_h"])
    ee = apply_norm(cfg.norm, e, m["norm_e"])
    z = jnp.concatenate([hh, ee], axis=-1) @ m["proj"]
    z, _, _ = tfm.layer_apply(cfg, rc, m["layer"], None, z, kind="attn",
                              ffn="dense", positions=jnp.arange(S - 1),
                              cond=None, make_cache_len=0)
    z = apply_norm(cfg.norm, z, m["final_norm"])
    logits = _head(cfg, params, z)
    labels = jnp.concatenate(
        [tokens[:, 2:], jnp.full((B, 1), -1, tokens.dtype)], axis=1)
    return cross_entropy(logits, labels, vocab_real=cfg.vocab)


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def prefill(cfg: ArchConfig, rc: RunConfig, params, biases, batch, max_len: int):
    """-> (cache, last_logits)."""
    logits, cache, _, _ = forward(cfg, rc, params, biases, batch,
                                  make_cache_len=max_len)
    return cache, logits[:, -1]


def decode_step(cfg: ArchConfig, rc: RunConfig, params, biases, cache,
                token, pos):
    """token: [B,1] int32, pos: scalar int32 -> (logits [B,Vp], cache)."""
    pvec = jnp.zeros((1,), jnp.int32) + pos
    x = _embed(cfg, params, token, pvec)
    x, cache = tfm.stack_decode(cfg, rc, params["stack"], biases, cache, x, pos)
    x = apply_norm(cfg.norm, x, params.get("final_norm"))
    logits = _head(cfg, params, x)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins) and cache
# ---------------------------------------------------------------------------

def cache_abstract(cfg: ArchConfig, batch: int, max_len: int):
    return abstract_params(tfm.cache_schema(cfg, batch, max_len))


def cache_shardings(cfg: ArchConfig, batch: int, max_len: int, mesh, rules):
    return sharding_tree(tfm.cache_schema(cfg, batch, max_len), mesh, rules)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    return init_params(tfm.cache_schema(cfg, batch, max_len),
                       jax.random.PRNGKey(0))


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh=None, rules=None):
    """ShapeDtypeStructs (with shardings when a mesh is given) for batch inputs."""
    B, S = shape.global_batch, shape.seq_len

    def sds(shp, dtype, dims):
        if mesh is None:
            return jax.ShapeDtypeStruct(shp, dtype)
        spec = spec_for(shp, dims, mesh, rules)
        return jax.ShapeDtypeStruct(shp, dtype, sharding=NamedSharding(mesh, spec))

    if shape.kind == "decode":
        batch = {"tokens": sds((B, 1), jnp.int32, ("batch", None))}
    else:
        batch = {"tokens": sds((B, S), jnp.int32, ("batch", None))}
        if cfg.cross_attn:
            batch["cond"] = sds((B, cfg.cond_len, cfg.d_model), jnp.bfloat16,
                                ("batch", None, None))
        if cfg.prefix_embeds:
            batch["prefix"] = sds((B, cfg.prefix_embeds, cfg.d_model),
                                  jnp.bfloat16, ("batch", None, None))
    return batch


# ---------------------------------------------------------------------------
# Analytic param counts (MODEL_FLOPS = 6 * N * D uses non-embedding params)
# ---------------------------------------------------------------------------

def count_params_analytic(cfg: ArchConfig, active_only: bool = False) -> int:
    ps, _ = model_schema(cfg)
    total = 0
    moe_frac = 1.0
    if cfg.moe is not None and active_only:
        moe_frac = cfg.moe.top_k / cfg.moe.n_experts

    def add(path, pd: ParamDef):
        nonlocal total
        n = int(np.prod(pd.shape))
        sp = "/".join(map(str, path))
        if "embed" in sp or (not cfg.tie_embeddings and sp.startswith("head")):
            return None                       # embeddings excluded from 6ND
        if "/moe/" in f"/{sp}/" and "shared" not in sp and "router" not in sp:
            n = int(n * moe_frac)
        total += n
        return None

    tree_map_schema(add, ps)
    return total


def count_params_total(cfg: ArchConfig) -> int:
    ps, _ = model_schema(cfg)
    total = 0

    def add(path, pd: ParamDef):
        nonlocal total
        total += int(np.prod(pd.shape))
        return None

    tree_map_schema(add, ps)
    return total
