"""Composable decoder stack.

Layers are grouped into *units* (the arch's repeating pattern); consecutive identical
units are stacked and run under ``lax.scan`` (bounded compile time at 61 layers), with
per-unit ``jax.checkpoint`` (remat). Heterogeneous prefixes/suffixes (DeepSeek's 3 dense
layers, RecurrentGemma's trailing (rglru, rglru)) become separate scan groups / an
unrolled tail.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunConfig
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import apply_norm, norm_schema
from repro.parallel.sharding import ParamDef, shard_act, tree_map_schema


# ---------------------------------------------------------------------------
# Layer planning
# ---------------------------------------------------------------------------

def ffn_kind(cfg: ArchConfig, layer_idx: int) -> str:
    if cfg.moe is not None:
        return "moe" if layer_idx >= cfg.moe.start_layer else (
            "dense" if cfg.d_ff else "none")
    if cfg.pattern[layer_idx % len(cfg.pattern)] == "ssm":
        return "none"
    return "dense" if cfg.d_ff else "none"


def plan_layers(cfg: ArchConfig):
    """-> (groups: list[(unit_sig, count)], tail: unit_sig|None).

    unit_sig = tuple of (kind, ffn) per layer in the unit.
    """
    n = cfg.n_layers
    u = len(cfg.pattern)
    kinds = cfg.layer_kinds
    ffns = [ffn_kind(cfg, i) for i in range(n)]
    full = n - (n % u)
    units = [tuple(zip(kinds[i:i + u], ffns[i:i + u])) for i in range(0, full, u)]
    tail = tuple(zip(kinds[full:], ffns[full:])) if n % u else None
    groups: list[tuple[tuple, int]] = []
    for sig in units:
        if groups and groups[-1][0] == sig:
            groups[-1] = (sig, groups[-1][1] + 1)
        else:
            groups.append((sig, 1))
    return groups, tail


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------

def layer_schema(cfg: ArchConfig, kind: str, ffn: str) -> dict:
    D = cfg.d_model
    s: dict = {"norm1": norm_schema(cfg.norm, D)}
    if kind in ("attn", "local"):
        s["attn"] = attn_mod.attn_schema(cfg, kind)
        if cfg.cross_attn:
            s["norm_x"] = norm_schema(cfg.norm, D)
            s["cross"] = attn_mod.attn_schema(cfg, "cross")
    elif kind == "ssm":
        s["ssm"] = ssm_mod.ssm_schema(cfg)
    elif kind == "rglru":
        s["rec"] = rglru_mod.rglru_schema(cfg)
    else:
        raise ValueError(kind)
    if cfg.post_block_norm:
        s["post1"] = norm_schema(cfg.norm, D)
    if ffn != "none":
        s["norm2"] = norm_schema(cfg.norm, D)
        if ffn == "moe":
            s["moe"] = moe_mod.moe_schema(cfg)
        else:
            s["ffn"] = ffn_mod.ffn_schema(cfg)
        if cfg.post_block_norm:
            s["post2"] = norm_schema(cfg.norm, D)
    return s


def unit_schema(cfg: ArchConfig, sig) -> dict:
    return {f"l{i}": layer_schema(cfg, k, f) for i, (k, f) in enumerate(sig)}


def _stack_schema(schema, n: int):
    return tree_map_schema(
        lambda path, pd: ParamDef((n,) + pd.shape, ("layers",) + pd.dims,
                                  init=pd.init, scale=pd.scale, dtype=pd.dtype),
        schema)


def unit_bias_schema(cfg: ArchConfig, sig) -> dict:
    """Router-bias extras (aux-loss-free routing state), mirroring moe layers."""
    out = {}
    for i, (k, f) in enumerate(sig):
        if f == "moe":
            out[f"l{i}"] = moe_mod.moe_bias_def(cfg)
    return out


def stack_schema_for_groups(cfg: ArchConfig):
    groups, tail = plan_layers(cfg)
    params = {}
    biases = {}
    for gi, (sig, cnt) in enumerate(groups):
        params[f"g{gi}"] = _stack_schema(unit_schema(cfg, sig), cnt)
        b = unit_bias_schema(cfg, sig)
        if b:
            biases[f"g{gi}"] = _stack_schema(b, cnt)
    if tail is not None:
        params["tail"] = unit_schema(cfg, tail)
        b = unit_bias_schema(cfg, tail)
        if b:
            biases["tail"] = b
    return params, biases, groups, tail


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _maybe_post(cfg, p, key, y):
    if cfg.post_block_norm:
        return apply_norm(cfg.norm, y, p.get(key))
    return y


def layer_apply(cfg: ArchConfig, rc: RunConfig, p: dict, bias, x, *,
                kind: str, ffn: str, positions, cond, make_cache_len: int):
    """Full-sequence path (train / prefill). Returns (x, cache, aux)."""
    cache: dict = {}
    aux: dict = {}
    h = apply_norm(cfg.norm, x, p.get("norm1"))
    if kind in ("attn", "local"):
        y, c = attn_mod.gqa_or_mla_apply(
            cfg, p["attn"], h, kind=kind, positions=positions,
            impl=rc.attention_impl_for(h.shape[1]), chunk=rc.attn_chunk,
            make_cache=make_cache_len)
        if c:
            cache["attn"] = c
    elif kind == "ssm":
        y, c = ssm_mod.ssm_apply(cfg, p["ssm"], h, make_cache=bool(make_cache_len))
        if c:
            cache["ssm"] = c
    elif kind == "rglru":
        y, c = rglru_mod.rglru_apply(cfg, p["rec"], h,
                                     make_cache=bool(make_cache_len))
        if c:
            cache["rec"] = c
    else:
        raise ValueError(kind)
    x = x + _maybe_post(cfg, p, "post1", y)

    if cfg.cross_attn and kind in ("attn", "local"):
        hx = apply_norm(cfg.norm, x, p.get("norm_x"))
        y, c = attn_mod.gqa_apply(cfg, p["cross"], hx, kind="cross",
                                  positions=positions, impl="masked",
                                  chunk=rc.attn_chunk, cond=cond,
                                  make_cache=make_cache_len)
        if c:
            cache["cross"] = c
        x = x + y

    if ffn != "none":
        h = apply_norm(cfg.norm, x, p.get("norm2"))
        if ffn == "moe":
            y, moe_aux = moe_mod.moe_apply(cfg, p["moe"], h, bias,
                                           compress_a2a=rc.compress_moe_a2a)
            aux.update(moe_aux)
        else:
            y = ffn_mod.ffn_apply(cfg, p["ffn"], h)
        x = x + _maybe_post(cfg, p, "post2", y)
    x = shard_act(x, ("batch", None, None))
    return x, cache, aux


def layer_decode(cfg: ArchConfig, rc: RunConfig, p: dict, bias, cache: dict,
                 x1, pos, *, kind: str, ffn: str):
    """Single-token path. Returns (x1, new_cache)."""
    new_cache: dict = {}
    h = apply_norm(cfg.norm, x1, p.get("norm1"))
    if kind in ("attn", "local"):
        y, c = attn_mod.gqa_or_mla_decode(cfg, p["attn"], h, cache["attn"], pos,
                                          kind=kind)
        new_cache["attn"] = c
    elif kind == "ssm":
        y, c = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache["ssm"], pos)
        new_cache["ssm"] = c
    elif kind == "rglru":
        y, c = rglru_mod.rglru_decode(cfg, p["rec"], h, cache["rec"], pos)
        new_cache["rec"] = c
    x1 = x1 + _maybe_post(cfg, p, "post1", y)

    if cfg.cross_attn and kind in ("attn", "local"):
        hx = apply_norm(cfg.norm, x1, p.get("norm_x"))
        y, c = attn_mod.gqa_decode(cfg, p["cross"], hx, cache["cross"], pos,
                                   kind="cross")
        new_cache["cross"] = c
        x1 = x1 + y

    if ffn != "none":
        h = apply_norm(cfg.norm, x1, p.get("norm2"))
        if ffn == "moe":
            y, _ = moe_mod.moe_apply(cfg, p["moe"], h, bias,
                                     compress_a2a=rc.compress_moe_a2a)
        else:
            y = ffn_mod.ffn_apply(cfg, p["ffn"], h)
        x1 = x1 + _maybe_post(cfg, p, "post2", y)
    return x1, new_cache


# ---------------------------------------------------------------------------
# Stack application
# ---------------------------------------------------------------------------

def _unit_fns(cfg, rc, sig, positions, cond, make_cache_len):
    def unit_fn(x, unit_p, unit_b):
        caches, auxs = {}, {}
        for i, (k, f) in enumerate(sig):
            b = unit_b.get(f"l{i}") if unit_b else None
            x, c, a = layer_apply(cfg, rc, unit_p[f"l{i}"], b, x, kind=k, ffn=f,
                                  positions=positions, cond=cond,
                                  make_cache_len=make_cache_len)
            if c:
                caches[f"l{i}"] = c
            if a:
                auxs[f"l{i}"] = a
        return x, caches, auxs
    if rc.remat == "full":
        unit_fn = jax.checkpoint(unit_fn)
    elif rc.remat == "dots":
        unit_fn = jax.checkpoint(
            unit_fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return unit_fn


def stack_apply(cfg: ArchConfig, rc: RunConfig, params: dict, biases: dict, x, *,
                positions, cond=None, make_cache_len: int = 0):
    """Run all groups + tail. Returns (x, cache_tree, aux_tree)."""
    groups, tail = plan_layers(cfg)
    caches, auxs = {}, {}
    for gi, (sig, cnt) in enumerate(groups):
        key = f"g{gi}"
        unit_fn = _unit_fns(cfg, rc, sig, positions, cond, make_cache_len)
        bstack = biases.get(key)

        def body(carry, per):
            up, ub = per
            y, c, a = unit_fn(carry, up, ub)
            return y, (c, a)

        if bstack is not None:
            x, (c, a) = jax.lax.scan(body, x, (params[key], bstack))
        else:
            def body0(carry, up):
                y, c, a = unit_fn(carry, up, None)
                return y, (c, a)
            x, (c, a) = jax.lax.scan(body0, x, params[key])
        if jax.tree_util.tree_leaves(c):
            caches[key] = c
        if jax.tree_util.tree_leaves(a):
            auxs[key] = a
    if tail is not None:
        unit_fn = _unit_fns(cfg, rc, tail, positions, cond, make_cache_len)
        x, c, a = unit_fn(x, params["tail"], biases.get("tail"))
        if jax.tree_util.tree_leaves(c):
            caches["tail"] = c
        if jax.tree_util.tree_leaves(a):
            auxs["tail"] = a
    return x, caches, auxs


def stack_decode(cfg: ArchConfig, rc: RunConfig, params: dict, biases: dict,
                 cache: dict, x1, pos):
    groups, tail = plan_layers(cfg)
    new_cache = {}
    for gi, (sig, cnt) in enumerate(groups):
        key = f"g{gi}"

        def unit_dec(x, up, ub, uc):
            ncs = {}
            for i, (k, f) in enumerate(sig):
                b = ub.get(f"l{i}") if ub else None
                x, nc = layer_decode(cfg, rc, up[f"l{i}"], b,
                                     uc[f"l{i}"] if f"l{i}" in uc else {},
                                     x, pos, kind=k, ffn=f)
                if nc:
                    ncs[f"l{i}"] = nc
            return x, ncs

        bstack = biases.get(key)
        if bstack is not None:
            def body(carry, per):
                up, ub, uc = per
                return unit_dec(carry, up, ub, uc)
            x1, nc = jax.lax.scan(body, x1, (params[key], bstack, cache[key]))
        else:
            def body0(carry, per):
                up, uc = per
                return unit_dec(carry, up, None, uc)
            x1, nc = jax.lax.scan(body0, x1, (params[key], cache[key]))
        new_cache[key] = nc
    if tail is not None:
        ncs = {}
        x = x1
        for i, (k, f) in enumerate(tail):
            b = (biases.get("tail") or {}).get(f"l{i}")
            x, nc = layer_decode(cfg, rc, params["tail"][f"l{i}"], b,
                                 cache["tail"][f"l{i}"], x, pos, kind=k, ffn=f)
            if nc:
                ncs[f"l{i}"] = nc
        x1 = x
        new_cache["tail"] = ncs
    return x1, new_cache


# ---------------------------------------------------------------------------
# Cache defs
# ---------------------------------------------------------------------------

def cache_schema(cfg: ArchConfig, batch: int, max_len: int):
    """ParamDef tree matching the cache produced by prefill / consumed by decode."""
    groups, tail = plan_layers(cfg)
    out = {}

    def unit_cache(sig):
        u = {}
        for i, (k, f) in enumerate(sig):
            c = {}
            if k in ("attn", "local"):
                c["attn"] = attn_mod.cache_def(cfg, k, batch, max_len)
                if cfg.cross_attn:
                    c["cross"] = attn_mod.cache_def(cfg, "cross", batch, max_len)
            elif k == "ssm":
                c["ssm"] = ssm_mod.ssm_cache_def(cfg, batch)
            elif k == "rglru":
                c["rec"] = rglru_mod.rglru_cache_def(cfg, batch)
            if c:
                u[f"l{i}"] = c
        return u

    for gi, (sig, cnt) in enumerate(groups):
        out[f"g{gi}"] = _stack_schema(unit_cache(sig), cnt)
    if tail is not None:
        out["tail"] = unit_cache(tail)
    return out
