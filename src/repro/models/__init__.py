from repro.models import model
from repro.models.model import (
    model_schema, init, abstract, forward, loss_fn, prefill, decode_step,
    input_specs, cache_abstract, init_cache, count_params_analytic,
    count_params_total, param_shardings, cache_shardings,
)
