"""Dense feed-forward blocks (gated and plain)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import activate
from repro.parallel.sharding import ParamDef, shard_act


def ffn_schema(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    s = {
        "w_up": ParamDef((D, F), ("embed", "mlp")),
        "w_down": ParamDef((F, D), ("mlp", "embed")),
    }
    if cfg.gated_mlp:
        s["w_gate"] = ParamDef((D, F), ("embed", "mlp"))
    return s


def ffn_apply(cfg: ArchConfig, p: dict, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = activate(cfg.act, g) * h
    else:
        h = activate(cfg.act, h)
    h = shard_act(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
