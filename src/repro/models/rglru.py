"""Griffin RG-LRU recurrent block [arXiv:2402.19427] (RecurrentGemma).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_r u_t), i_t = sigmoid(W_i u_t)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
Training/prefill uses ``jax.lax.associative_scan`` over the sequence; decode is one
recurrence step (O(1) state — with the bounded local-attention window this makes
recurrentgemma the other ``long_500k``-eligible arch).

Gates use block-diagonal linears with n_heads blocks (as in the DeepMind impl).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import ParamDef, shard_act
from repro.models.ssm import _causal_conv


def rglru_schema(cfg: ArchConfig) -> dict:
    g = cfg.rglru
    D = cfg.d_model
    W = g.lru_width or D
    nb = cfg.n_heads
    bw = W // nb
    return {
        "w_in": ParamDef((D, W), ("embed", "state")),
        "w_gate_branch": ParamDef((D, W), ("embed", "state")),
        "conv": ParamDef((g.conv_width, W), (None, "state"), scale=0.5),
        "w_r": ParamDef((nb, bw, bw), (None, None, None)),
        "b_r": ParamDef((W,), (None,), init="zeros"),
        "w_i": ParamDef((nb, bw, bw), (None, None, None)),
        "b_i": ParamDef((W,), (None,), init="zeros"),
        "lam": ParamDef((W,), (None,), init="ones", dtype="float32"),
        "w_out": ParamDef((W, D), ("state", "embed")),
    }


def _block_linear(u, w, b):
    """u: [...,W], w: [nb,bw,bw] -> [...,W]."""
    nb, bw, _ = w.shape
    shp = u.shape
    ub = u.reshape(*shp[:-1], nb, bw)
    yb = jnp.einsum("...nk,nkj->...nj", ub, w)
    return yb.reshape(*shp) + b


def _gates(cfg: ArchConfig, p, u):
    g = cfg.rglru
    r = jax.nn.sigmoid(_block_linear(u, p["w_r"], p["b_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_linear(u, p["w_i"], p["b_i"]).astype(jnp.float32))
    log_a = -g.c * jax.nn.softplus(p["lam"]) * r          # [...,W] fp32, negative
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * u.astype(jnp.float32))
    return a, gated


def rglru_apply(cfg: ArchConfig, p: dict, x, *, make_cache: bool = False):
    """x: [B,L,D] -> (y, cache|None)."""
    B, L, D = x.shape
    u0 = jnp.einsum("bld,dw->blw", x, p["w_in"])
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, p["w_gate_branch"]))
    u = _causal_conv(u0, p["conv"])
    u = shard_act(u, ("batch", None, "state"))

    a, b = _gates(cfg, p, u)                               # [B,L,W] fp32

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (hh.astype(x.dtype) * gate)
    out = jnp.einsum("blw,wd->bld", y, p["w_out"])

    cache = None
    if make_cache:
        K = cfg.rglru.conv_width
        cache = {"conv": u0[:, -(K - 1):] if K > 1 else u0[:, :0],
                 "state": hh[:, -1]}                        # [B,W] fp32
    return out, cache


def rglru_cache_def(cfg: ArchConfig, batch: int) -> dict:
    g = cfg.rglru
    W = g.lru_width or cfg.d_model
    K = g.conv_width
    return {
        "conv": ParamDef((batch, K - 1, W), ("batch", None, "state"), init="zeros"),
        "state": ParamDef((batch, W), ("batch", "state"), init="zeros",
                          dtype="float32"),
    }


def rglru_decode(cfg: ArchConfig, p: dict, x1, cache: dict, pos):
    B, _, D = x1.shape
    x0 = x1[:, 0]
    u0 = x0 @ p["w_in"]
    gate = jax.nn.gelu(x0 @ p["w_gate_branch"])
    seq = jnp.concatenate([cache["conv"], u0[:, None]], axis=1)
    u = jnp.einsum("bkw,kw->bw", seq, p["conv"])
    a, b = _gates(cfg, p, u)
    h = a * cache["state"] + b
    y = (h.astype(x1.dtype) * gate)
    out = (y @ p["w_out"])[:, None, :]
    return out, {"conv": seq[:, 1:], "state": h}
