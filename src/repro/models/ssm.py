"""Mamba-2 block via the SSD (state-space duality) chunked algorithm
[arXiv:2405.21060].

Training/prefill uses the chunked form: intra-chunk quadratic attention-like term +
inter-chunk state recurrence (sequential scan over chunks). Decode keeps an O(1)
recurrent state per layer — which is why mamba2 is the arch that makes the
``long_500k`` cell feasible at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.common import rmsnorm
from repro.parallel.sharding import ParamDef, shard_act


def ssm_schema(cfg: ArchConfig) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    Din = s.d_inner(D)
    H = s.n_heads(D)
    N = s.d_state
    G = s.n_groups
    K = s.conv_width
    return {
        "w_z": ParamDef((D, Din), ("embed", "state")),
        "w_x": ParamDef((D, Din), ("embed", "state")),
        "w_B": ParamDef((D, G * N), ("embed", None)),
        "w_C": ParamDef((D, G * N), ("embed", None)),
        "w_dt": ParamDef((D, H), ("embed", "heads")),
        "conv_x": ParamDef((K, Din), (None, "state"), scale=0.5),
        "conv_B": ParamDef((K, G * N), (None, None), scale=0.5),
        "conv_C": ParamDef((K, G * N), (None, None), scale=0.5),
        "A_log": ParamDef((H,), ("heads",), init="zeros"),
        "D": ParamDef((H,), ("heads",), init="ones"),
        "dt_bias": ParamDef((H,), ("heads",), init="zeros"),
        "gn": ParamDef((Din,), ("state",), init="zeros"),
        "w_out": ParamDef((Din, D), ("state", "embed")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv via shifted adds. x: [B,L,C], w: [K,C]."""
    K = w.shape[0]
    y = x * w[K - 1]
    for i in range(1, K):
        y = y + jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i] * w[K - 1 - i]
    return y


def _segsum(a):
    """a: [..., Q]. Lower-triangular cumulative sums: out[i,j] = sum_{j<t<=i} a_t."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = np.tril(np.ones((Q, Q), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """SSD over chunks — sequential scan over the chunk dimension with a rematted
    body, so only one [B,H,Q,Q] decay tile is ever alive (the all-chunks-vectorized
    form materializes [B,nc,H,Q,Q] and dominated train-step memory).

    x: [B,L,H,P], dt: [B,L,H] (positive), A: [H] (negative), Bm/Cm: [B,L,G,N].
    Returns y: [B,L,H,P].
    """
    Bz, L, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    rep = H // G

    # [nc, B, Q, ...] chunked views (scan over leading dim)
    xc = jnp.moveaxis(x.reshape(Bz, nc, chunk, H, Pd), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bz, nc, chunk, H), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(Bz, nc, chunk, G, N), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(Bz, nc, chunk, G, N), 1, 0)

    @jax.checkpoint
    def body(S_prev, inp):
        xq, dtq, Bq, Cq = inp                       # [B,Q,H,P] etc.
        Bh = jnp.repeat(Bq, rep, axis=2)            # [B,Q,H,N]
        Ch = jnp.repeat(Cq, rep, axis=2)
        a = (dtq * A).astype(jnp.float32)           # [B,Q,H], negative
        a_t = jnp.moveaxis(a, -1, -2)               # [B,H,Q]
        acs = jnp.cumsum(a_t, axis=-1)
        xdt = (xq * dtq[..., None]).astype(jnp.float32)

        Ldec = jnp.exp(_segsum(a_t))                # [B,H,Q,Q]
        scores = jnp.einsum("bqhn,bkhn->bhqk", Ch, Bh,
                            preferred_element_type=jnp.float32)
        y_diag = jnp.einsum("bhqk,bhqk,bkhp->bqhp", scores, Ldec, xdt)

        dec_to_end = jnp.exp(acs[..., -1:] - acs)   # [B,H,Q]
        S_c = jnp.einsum("bkhn,bhk,bkhp->bhnp", Bh, dec_to_end, xdt)

        dec_from_start = jnp.exp(acs)               # [B,H,Q]
        y_off = jnp.einsum("bqhn,bhq,bhnp->bqhp",
                           Ch.astype(jnp.float32), dec_from_start, S_prev)

        chunk_decay = jnp.exp(acs[..., -1])         # [B,H]
        S = S_prev * chunk_decay[..., None, None] + S_c
        return S, (y_diag + y_off).astype(x.dtype)

    S0 = jnp.zeros((Bz, H, N, Pd), jnp.float32)
    _, yc = jax.lax.scan(body, S0, (xc, dtc, Bc, Cc))
    return jnp.moveaxis(yc, 0, 1).reshape(Bz, L, H, Pd)


def ssm_apply(cfg: ArchConfig, p: dict, x, *, make_cache: bool = False):
    """x: [B,L,D] -> (y, cache|None). Training / prefill path."""
    s = cfg.ssm
    B, L, D = x.shape
    H = s.n_heads(D)
    Pd = s.head_dim
    G, N = s.n_groups, s.d_state

    z = jnp.einsum("bld,de->ble", x, p["w_z"])
    xin = jnp.einsum("bld,de->ble", x, p["w_x"])
    Bm = jnp.einsum("bld,de->ble", x, p["w_B"])
    Cm = jnp.einsum("bld,de->ble", x, p["w_C"])
    dt = jnp.einsum("bld,dh->blh", x, p["w_dt"])

    xin = jax.nn.silu(_causal_conv(xin, p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"]))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = shard_act(xin.reshape(B, L, H, Pd), ("batch", None, "heads", None))
    Bh = Bm.reshape(B, L, G, N)
    Ch = Cm.reshape(B, L, G, N)

    chunk = min(s.chunk, L)
    pad = (-L) % chunk
    if pad:                    # causal: trailing pad cannot affect y[:, :L]
        xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh_p = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch_p = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y = ssd_chunked(xh_p, dt_p, A, Bh_p, Ch_p, chunk)[:, :L]
    else:
        y = ssd_chunked(xh, dt, A, Bh, Ch, chunk)
    y = y + xh * p["D"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(B, L, H * Pd)
    y = rmsnorm(y * jax.nn.silu(z), p["gn"])
    out = jnp.einsum("ble,ed->bld", y, p["w_out"])

    cache = None
    if make_cache:
        K = s.conv_width
        # final SSM state: recompute from the scan end (cheap: reuse chunked pieces)
        cache = {
            "conv_x": _tail(xin_pre := jnp.einsum("bld,de->ble", x, p["w_x"]), K - 1),
            "conv_B": _tail(jnp.einsum("bld,de->ble", x, p["w_B"]), K - 1),
            "conv_C": _tail(jnp.einsum("bld,de->ble", x, p["w_C"]), K - 1),
            "state": _final_state(xh, dt, A, Bh),
        }
    return out, cache


def _tail(x, k):
    return x[:, -k:] if k else x[:, :0]


def _final_state(xh, dt, A, Bh):
    """Exact final SSM state h_L: [B,H,N,P] (sequential over chunk ends)."""
    B, L, H, Pd = xh.shape
    G, N = Bh.shape[2], Bh.shape[3]
    rep = H // G
    Bfull = jnp.repeat(Bh, rep, axis=2)                  # [B,L,H,N]
    a = (dt * A).astype(jnp.float32)                     # [B,L,H]
    acs = jnp.cumsum(a, axis=1)
    dec = jnp.exp(acs[:, -1:, :] - acs)                  # decay from t to end
    xdt = (xh * dt[..., None]).astype(jnp.float32)
    S = jnp.einsum("blhn,blh,blhp->bhnp", Bfull.astype(jnp.float32), dec, xdt)
    return S


def ssm_cache_def(cfg: ArchConfig, batch: int) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    Din, H, N, G, K = (s.d_inner(D), s.n_heads(D), s.d_state, s.n_groups,
                       s.conv_width)
    return {
        "conv_x": ParamDef((batch, K - 1, Din), ("batch", None, "state"),
                           init="zeros"),
        "conv_B": ParamDef((batch, K - 1, G * N), ("batch", None, None),
                           init="zeros"),
        "conv_C": ParamDef((batch, K - 1, G * N), ("batch", None, None),
                           init="zeros"),
        "state": ParamDef((batch, H, N, s.head_dim), ("batch", "heads", None, None),
                          init="zeros", dtype="float32"),
    }


def ssm_decode(cfg: ArchConfig, p: dict, x1, cache: dict, pos):
    """Single-token recurrent step. x1: [B,1,D]."""
    s = cfg.ssm
    B, _, D = x1.shape
    H, Pd, G, N, K = (s.n_heads(D), s.head_dim, s.n_groups, s.d_state,
                      s.conv_width)
    x0 = x1[:, 0]
    z = x0 @ p["w_z"]
    xin = x0 @ p["w_x"]
    Bm = x0 @ p["w_B"]
    Cm = x0 @ p["w_C"]
    dt = x0 @ p["w_dt"]

    def conv_step(prev, cur, w):
        seq = jnp.concatenate([prev, cur[:, None]], axis=1)   # [B,K,C]
        out = jnp.einsum("bkc,kc->bc", seq, w)
        return jax.nn.silu(out), seq[:, 1:]

    xin, cx = conv_step(cache["conv_x"], xin, p["conv_x"])
    Bm, cB = conv_step(cache["conv_B"], Bm, p["conv_B"])
    Cm, cC = conv_step(cache["conv_C"], Cm, p["conv_C"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(B, H, Pd).astype(jnp.float32)
    Bh = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)

    dA = jnp.exp(dt * A)                                      # [B,H]
    h = cache["state"] * dA[..., None, None] + \
        jnp.einsum("bhn,bh,bhp->bhnp", Bh, dt, xh)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h) + xh * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(B, H * Pd).astype(x1.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gn"])
    out = (y @ p["w_out"])[:, None, :]
    return out, {"conv_x": cx, "conv_B": cB, "conv_C": cC, "state": h}
