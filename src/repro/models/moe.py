"""Mixture-of-Experts with explicit expert parallelism.

Experts are sharded over the ``model`` mesh axis (EP); tokens live on the ``data``
(+``pod``) axes. Dispatch is a capacity-based sort-free scatter: per destination
expert-shard send buffers are filled by cumulative-position, exchanged with
``lax.all_to_all`` over ``model``, locally re-bucketed per expert, run through batched
expert GEMMs, and returned the same way. Everything happens inside one ``shard_map``
(manual over all mesh axes) so the collective schedule is explicit and auditable in the
lowered HLO — this is the analogue of Hadoop's shuffle, and the place where the paper's
LZO insight lands: ``compress_a2a`` quantizes the a2a payload to int8 (fwd and bwd),
halving wire bytes on the slowest link at the cost of cheap VPU math.

Expert weights are sharded over the FSDP axes on their hidden dim and all-gathered once
per layer inside the body (ZeRO-3 style), mirroring what GSPMD does for the dense path.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.common import activate
from repro.parallel.sharding import (
    ParamDef, batch_axes, current_mesh, current_rules)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def moe_schema(cfg: ArchConfig) -> dict:
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts_padded, m.d_ff_expert
    s = {
        "router": ParamDef((D, E), (None, None), dtype="float32"),
        "w_gate": ParamDef((E, D, F), ("experts", None, "expert_ff")),
        "w_up": ParamDef((E, D, F), ("experts", None, "expert_ff")),
        "w_down": ParamDef((E, F, D), ("experts", "expert_ff", None)),
    }
    if m.n_shared:
        from repro.models.ffn import ffn_schema
        s["shared"] = ffn_schema(cfg, d_ff=m.d_ff_shared * m.n_shared)
    return s


def moe_bias_def(cfg: ArchConfig) -> ParamDef:
    """Aux-loss-free router bias (DeepSeek): non-gradient state, updated per step."""
    return ParamDef((cfg.moe.n_experts_padded,), (None,), init="zeros",
                    dtype="float32")


# ---------------------------------------------------------------------------
# Compressed all-to-all (LZO analogue): int8 payload fwd AND bwd
# ---------------------------------------------------------------------------

def _q8(x):
    ax = tuple(range(1, x.ndim))
    scale = jnp.max(jnp.abs(x), axis=ax, keepdims=True).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def compressed_all_to_all(x, axis_name: str, enabled: bool):
    return _ca2a_fwd(x, axis_name, enabled)[0]


def _a2a(x, axis_name):
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)


def _ca2a_fwd(x, axis_name, enabled):
    if not enabled:
        return _a2a(x, axis_name), None
    q, scale = _q8(x)
    q = _a2a(q, axis_name)
    scale = _a2a(scale, axis_name)
    return _dq8(q, scale, x.dtype), None

def _ca2a_bwd(axis_name, enabled, _, g):
    if not enabled:
        return (_a2a(g, axis_name),)
    q, scale = _q8(g)
    q = _a2a(q, axis_name)
    scale = _a2a(scale, axis_name)
    return (_dq8(q, scale, g.dtype),)

compressed_all_to_all.defvjp(_ca2a_fwd, _ca2a_bwd)


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def route(m: MoEConfig, logits, bias):
    """logits: [n, E_pad] fp32. Returns (gates [n,K], ids [n,K], probs [n,E])."""
    E, Epad = m.n_experts, m.n_experts_padded
    neg = jnp.full((Epad - E,), -1e9, jnp.float32)
    pad_mask = jnp.concatenate([jnp.zeros((E,), jnp.float32), neg])
    logits = logits.astype(jnp.float32) + pad_mask
    if m.router == "sigmoid_bias":
        s = jax.nn.sigmoid(logits)
        sel_score = s + jax.lax.stop_gradient(bias) + pad_mask
        _, ids = jax.lax.top_k(sel_score, m.top_k)
        g = jnp.take_along_axis(s, ids, axis=-1)
        g = g / jnp.maximum(jnp.sum(g, axis=-1, keepdims=True), 1e-9)
        g = g * m.routed_scaling
        probs = s
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        g, ids = jax.lax.top_k(probs, m.top_k)
        g = g / jnp.maximum(jnp.sum(g, axis=-1, keepdims=True), 1e-9)
    return g, ids, probs


# ---------------------------------------------------------------------------
# The expert-parallel body (runs under shard_map, all axes manual)
# ---------------------------------------------------------------------------

def _moe_body(cfg: ArchConfig, compress_a2a: bool, ba: tuple, fsdp: tuple,
              x, router_w, bias, w_gate, w_up, w_down, rank_arr):
    """x: [T_loc, D] local tokens. w_*: [E_loc, ...] local expert shards
    (hidden dim F sharded over the FSDP axes -> gathered here).
    Returns (y [T_loc, D], load [E_pad] global token counts, aux_loss scalar)."""
    m = cfg.moe
    mesh = current_mesh()
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1

    if fsdp:
        w_gate = jax.lax.all_gather(w_gate, fsdp, axis=2, tiled=True)
        w_up = jax.lax.all_gather(w_up, fsdp, axis=2, tiled=True)
        w_down = jax.lax.all_gather(w_down, fsdp, axis=1, tiled=True)

    T_loc, D = x.shape
    Epad = m.n_experts_padded
    E_loc = Epad // tp
    K = m.top_k
    n = min(m.chunk_tokens, T_loc)
    nch = -(-T_loc // n)
    Tp = nch * n
    xp = jnp.pad(x, ((0, Tp - T_loc), (0, 0))) if Tp != T_loc else x
    xc = xp.reshape(nch, n, D)

    # tokens are replicated over the model axis; each rank dispatches only its
    # 1/tp slice (otherwise every rank routes ALL tokens and the expert GEMMs +
    # a2a payloads are duplicated tp times — the §Perf cell-A finding)
    slice_tokens = tp > 1 and n % tp == 0
    ntok = n // tp if slice_tokens else n
    A = ntok * K
    C_send = max(8, int(math.ceil(A / tp * m.capacity_factor / 8.0)) * 8)
    rows = tp * C_send
    C_exp = max(8, int(math.ceil(rows / E_loc * m.capacity_factor / 8.0)) * 8)
    # rank via sharded-iota argument (axis_index inside nested partial-manual
    # shard_map trips the sdy verifier)
    rank = rank_arr[0]

    @jax.checkpoint
    def chunk_fn(_, xt_full):
        xt = (jax.lax.dynamic_slice_in_dim(xt_full, rank * ntok, ntok, axis=0)
              if slice_tokens else xt_full)
        logits = xt.astype(jnp.float32) @ router_w          # [ntok, Epad]
        gates, ids, probs = route(m, logits, bias)
        dest = ids // E_loc                                  # [n, K]
        df = dest.reshape(A)
        oh = jax.nn.one_hot(df, tp, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - oh
        posd = jnp.sum(pos * oh, axis=1)                     # [A] position in dest
        keep = posd < C_send
        tok_idx = jnp.repeat(jnp.arange(ntok), K)
        slot = jnp.where(keep, df * C_send + posd, rows)
        xs = jnp.zeros((rows + 1, D), xt.dtype).at[slot].add(
            xt[tok_idx] * keep[:, None].astype(xt.dtype))
        es = jnp.zeros((rows + 1,), jnp.int32).at[slot].set(
            jnp.where(keep, ids.reshape(A) + 1, 0))
        xs = xs[:rows].reshape(tp, C_send, D)
        es = es[:rows].reshape(tp, C_send)

        if tp > 1:
            xr = compressed_all_to_all(xs, "model", compress_a2a)
            er = _a2a(es, "model")
        else:
            xr, er = xs, es

        # local per-expert bucketing
        xr2 = xr.reshape(rows, D)
        er2 = er.reshape(rows)
        valid = er2 > 0
        e_loc = jnp.clip(er2 - 1 - rank * E_loc, 0, E_loc - 1)
        oh2 = jax.nn.one_hot(e_loc, E_loc, dtype=jnp.int32) * valid[:, None]
        pos2 = jnp.cumsum(oh2, axis=0) - oh2
        p2 = jnp.sum(pos2 * oh2, axis=1)
        keep2 = valid & (p2 < C_exp)
        slot2 = jnp.where(keep2, e_loc * C_exp + p2, E_loc * C_exp)
        buf = jnp.zeros((E_loc * C_exp + 1, D), xt.dtype).at[slot2].add(
            xr2 * keep2[:, None].astype(xt.dtype))
        buf = buf[:-1].reshape(E_loc, C_exp, D)

        h = jnp.einsum("ecd,edf->ecf", buf, w_up)
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        h = activate(cfg.act, g) * h
        ob = jnp.einsum("ecf,efd->ecd", h, w_down)

        ob_flat = jnp.concatenate(
            [ob.reshape(E_loc * C_exp, D), jnp.zeros((1, D), ob.dtype)])
        back_rows = ob_flat[slot2] * keep2[:, None].astype(ob.dtype)
        back = back_rows.reshape(tp, C_send, D)
        if tp > 1:
            back = compressed_all_to_all(back, "model", compress_a2a)

        back_flat = jnp.concatenate(
            [back.reshape(rows, D), jnp.zeros((1, D), back.dtype)])
        y_a = back_flat[slot] * keep[:, None].astype(back.dtype)
        y = jnp.sum(y_a.reshape(ntok, K, D) *
                    gates.reshape(ntok, K, 1).astype(back.dtype), axis=1)
        if slice_tokens:     # reassemble the model-replicated token dim
            y = jax.lax.all_gather(y, "model", axis=0, tiled=True)

        load = jnp.sum(jax.nn.one_hot(ids.reshape(A), Epad, dtype=jnp.float32),
                       axis=0)
        me = jnp.mean(probs, axis=0)
        ce = load / jnp.maximum(jnp.sum(load), 1.0)
        aux = jnp.sum(me * ce) * (m.n_experts ** 1)
        return None, (y, load, aux)

    _, (yc, loads, auxs) = jax.lax.scan(chunk_fn, None, xc)
    y = yc.reshape(Tp, D)[:T_loc]
    load = jnp.sum(loads, axis=0)
    aux = jnp.mean(auxs)
    # global statistics
    if ba:
        load = jax.lax.psum(load, ba)
        aux = jax.lax.pmean(aux, ba)
    if tp > 1:
        if slice_tokens:
            load = jax.lax.psum(load, "model")    # ranks count disjoint slices
        else:
            load = jax.lax.psum(load, "model") / tp   # duplicated dispatch
        aux = jax.lax.pmean(aux, "model")
    return y, load, aux


def _expert_ff_axes(cfg: ArchConfig, mesh) -> tuple[str, ...]:
    """Mesh axes the expert hidden dim is actually sharded over (divisibility)."""
    rules = current_rules()
    axes = rules.axes_for("expert_ff") if rules else ()
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return ()
    prod = int(np.prod([mesh.shape[a] for a in axes]))
    return axes if cfg.moe.d_ff_expert % prod == 0 else ()


# ---------------------------------------------------------------------------
# Public apply
# ---------------------------------------------------------------------------

def moe_apply(cfg: ArchConfig, p: dict, x, bias, *, compress_a2a: bool = False):
    """x: [B,S,D] -> (y, aux dict). Runs the EP body under shard_map."""
    m = cfg.moe
    mesh = current_mesh()
    assert mesh is not None, "moe_apply requires a mesh context (use_mesh)"
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    ba = batch_axes(mesh)
    bs = ba if len(ba) > 1 else (ba[0] if ba else None)
    tok_spec = P(bs, None)
    rules = current_rules()
    eff = _expert_ff_axes(cfg, mesh)
    eff_s = eff if len(eff) > 1 else (eff[0] if eff else None)
    ex_ax = "model" if "model" in mesh.axis_names else None

    from repro.parallel.sharding import sharding_mesh
    manual = {a for a in (("model",) if ex_ax else ()) + tuple(ba) + tuple(eff)}
    body = functools.partial(_moe_body, cfg, compress_a2a, tuple(ba), tuple(eff))
    tp_size = mesh.shape["model"] if "model" in mesh.axis_names else 1
    from repro.core.compat import shard_map as shard_map_compat
    y, load, aux = shard_map_compat(
        body,
        mesh=sharding_mesh(),
        in_specs=(tok_spec, P(None, None), P(None),
                  P(ex_ax, None, eff_s), P(ex_ax, None, eff_s),
                  P(ex_ax, eff_s, None), P(ex_ax)),
        out_specs=(tok_spec, P(None), P()),
        axis_names=frozenset(manual),
    )(xt, p["router"], bias, p["w_gate"], p["w_up"], p["w_down"],
      jnp.arange(max(tp_size, 1), dtype=jnp.int32))
    y = y.reshape(B, S, D)

    if m.n_shared:
        from repro.models.ffn import ffn_apply
        y = y + ffn_apply(cfg, p["shared"], x)

    aux_out = {"load": jax.lax.stop_gradient(load),
               "aux_loss": aux * m.aux_loss_coef if m.aux_loss_coef else
               jnp.zeros((), jnp.float32)}
    return y, aux_out


def update_router_bias(m: MoEConfig, bias, load, *, gamma: float = 0.001):
    """Aux-loss-free bias update (DeepSeek-V3): push load toward uniform."""
    target = jnp.sum(load) / m.n_experts
    real = jnp.concatenate([jnp.ones((m.n_experts,)),
                            jnp.zeros((m.n_experts_padded - m.n_experts,))])
    delta = gamma * jnp.sign(target - load)
    return (bias + delta * real).astype(bias.dtype)
