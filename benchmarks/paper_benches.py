"""One benchmark per paper exhibit. Each emits ``name,us_per_call,derived`` CSV rows.

Paper exhibit -> TPU-framework analogue:
  Figure 1 (direct I/O vs page cache)   -> fig1: donated vs copied state update
  Table 2  (network I/O is CPU-heavy)   -> table2: wire bytes flat/hier/int8 sync
  Figure 2 (HDFS throughput vs mappers) -> fig2: pipeline throughput vs hosts
  Figure 3 (buffering/LZO/direct I/O)   -> fig3: zones app with batching/compression
  Table 3  (app runtimes vs theta)      -> table3: neighbor search/stats vs radius
  Table 4  (Amdahl numbers per task)    -> table4: balance table from dry-run artifacts
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _t(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6, out


def _subprocess_bench_json(script: str, error_name: str):
    """Run a multi-device bench snippet in a subprocess (forced host
    devices need their own process) and parse its last stdout line as
    JSON. -> (data, None) on success, (None, error_row) on failure."""
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900, env=env)
    if r.returncode != 0:
        return None, (error_name, 0.0, r.stderr.strip()[-120:])
    return json.loads(r.stdout.strip().splitlines()[-1]), None


def fig1_direct_io():
    """Donation (direct I/O analogue): in-place update vs copy on a 64MB state."""
    rows = []
    x = jnp.zeros((16 << 20,), jnp.float32)              # 64 MB
    g = jnp.ones_like(x) * 1e-3

    upd = lambda s, g: s * 0.999 + g
    f_copy = jax.jit(upd)
    f_donate = jax.jit(upd, donate_argnums=(0,))

    us_copy, _ = _t(lambda: f_copy(x, g), reps=10)
    state = x
    def donate_step():
        nonlocal state
        state = f_donate(state, g)
        return state
    us_don, _ = _t(donate_step, reps=10)
    rows.append(("fig1_update_copy", us_copy, f"bytes_moved={x.nbytes*2}"))
    rows.append(("fig1_update_donated", us_don,
                 f"bytes_moved={x.nbytes}_alias_in_place"))
    return rows


def table2_network():
    """Collective wire bytes for flat vs hierarchical vs int8 sync of a 64MB
    gradient on a 2x2x2 mesh (analyzed from SPMD HLO in a subprocess)."""
    script = r"""
import os
os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, %r)
import json, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.collectives import hierarchical_psum_1d
from repro.core.compat import make_mesh, shard_map
from repro.core.compression import compressed_psum_1d
from repro.core.hlo_analysis import analyze_hlo
mesh = make_mesh((2,2,2), ("pod","data","model"))
n = 16 << 20
x = jax.ShapeDtypeStruct((n,), jnp.float32)
out = {}
for name, body in {
  "flat": lambda v: jax.lax.psum(v, ("pod","data")),
  "hier": lambda v: hierarchical_psum_1d(v, "data", "pod"),
  "hier_int8": lambda v: hierarchical_psum_1d(v, "data", "pod", codec="int8"),
}.items():
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                          axis_names=frozenset({"pod","data"})))
    hlo = f.lower(x).compile().as_text()
    a = analyze_hlo(hlo, pod_size=4)
    out[name] = {"intra": a.coll_wire_intra, "cross": a.coll_wire_cross}
print(json.dumps(out))
""" % (os.path.join(ROOT, "src"),)
    data, err = _subprocess_bench_json(script, "table2_error")
    if err:
        return [err]
    return [(f"table2_sync_{name}", 0.0,
             f"wire_intra={d['intra']:.3g}_cross={d['cross']:.3g}")
            for name, d in data.items()]


def fig2_pipeline():
    """Data pipeline throughput vs number of reader hosts (HDFS mappers)."""
    from repro.data import Pipeline, PipelineConfig, SyntheticTokens, MemmapTokens
    rows = []
    B, S = 48, 1024            # divisible by 1..3 hosts
    for n_hosts in (1, 2, 3):
        src = SyntheticTokens(50000, 0)
        pipes = [Pipeline(src, PipelineConfig(B, S, host_id=h, n_hosts=n_hosts))
                 for h in range(n_hosts)]
        t0 = time.perf_counter()
        steps = 20
        for s in range(steps):
            for p in pipes:
                p.batch_at(s)
        dt = time.perf_counter() - t0
        mbs = steps * B * S * 4 / dt / 1e6
        rows.append((f"fig2_synthetic_{n_hosts}hosts", dt / steps * 1e6,
                     f"{mbs:.0f}MBps"))
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tok.bin")
        MemmapTokens.write(path, np.random.randint(0, 1000, (256, S)))
        src = MemmapTokens(path, S)
        pipe = Pipeline(src, PipelineConfig(B, S))
        t0 = time.perf_counter()
        for s in range(20):
            pipe.batch_at(s)
        dt = time.perf_counter() - t0
        rows.append(("fig2_memmap_1host", dt / 20 * 1e6,
                     f"{20*B*S*4/dt/1e6:.0f}MBps"))
    return rows


def fig3_improvements():
    """Neighbor Searching with the paper's improvements applied stepwise —
    each variant is the SAME job with a stage swapped (block size via tile /
    zone_height, shuffle codec via the registry), through the Job API's
    device engine. Each variant reports the best of 5 timed runs after one
    warmup (the warmup/rep convention ``_t`` applies to every other bench);
    lossy codecs are labeled ``exact=False`` with their pair-count delta vs
    the identity-codec row (int8's silent ~3x overcount in PR1 is now
    visible in the row itself)."""
    from repro.data import sky
    from repro.mapreduce import get_codec, neighbor_search_job, run_job
    xyz = sky.make_catalog(20000, 0)
    radius = 0.02
    rows = []
    variants = {
        # buffering analogue = the paper's block-size tuning ("always favor larger
        # blocks"): 4x-taller zones -> fewer, fuller buckets, less border copying
        "baseline": dict(tile=64, codec="identity"),
        "bigger_blocks": dict(tile=256, zone_height=4 * radius),
        "compressed_int16": dict(tile=64, codec="int16"),    # LZO analogue
        "compressed_int8": dict(tile=64, codec="int8"),      # heavier codec
        "blocks+int16": dict(tile=256, zone_height=4 * radius, codec="int16"),
    }
    base_pairs = None
    for name, kw in variants.items():
        job = neighbor_search_job(radius, **kw)
        run_job(job, xyz)                       # warmup (compile caches)
        res = min((run_job(job, xyz) for _ in range(5)),
                  key=lambda r: r.stats.wall_s)
        st = res.stats
        if base_pairs is None:
            base_pairs = int(res.output)
        codec = get_codec(job.codec)
        lossy = ("" if codec.exact else
                 f"_exact=False_dpairs={int(res.output) - base_pairs:+d}")
        rows.append((f"fig3_{name}", st.wall_s * 1e6,
                     f"pairs={res.output}_shuffleB={st.shuffle_wire_bytes}"
                     f"_ratio={st.compression_ratio:.1f}"
                     f"_domstage={st.dominant_stage}"
                     f"_padratio={st.reduce_padded_ratio:.2f}{lossy}"))
    rows += _fig3_sharded()
    return rows


def _fig3_sharded():
    """Sharded-mesh rows for fig3: the SAME search job on an 8-shard data
    mesh through both engines (subprocess, 8 forced host devices), so the
    device-vs-host crossover under sharding — the paper's "spread the
    reduce across more cores" claim — is measurable next to the
    single-device rows. Same warmup + best-of-5 convention."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, %r)
import json
import numpy as np
from repro.core.compat import make_mesh
from repro.data import sky
from repro.mapreduce import neighbor_search_job, run_job

mesh = make_mesh((8,), ("data",))
xyz = sky.make_catalog(20000, 0)
job = neighbor_search_job(0.02, tile=64, codec="int16")
out = {}
for engine in ("device", "host"):
    run_job(job, xyz, mesh=mesh, engine=engine)            # warmup
    res = min((run_job(job, xyz, mesh=mesh, engine=engine)
               for _ in range(5)), key=lambda r: r.stats.wall_s)
    st = res.stats
    out[engine] = {"us": st.wall_s * 1e6, "pairs": int(res.output),
                   "n_shards": st.n_shards,
                   "maxshardpad": max(st.shard_padded_ratio)}
print(json.dumps(out))
""" % (os.path.join(ROOT, "src"),)
    data, err = _subprocess_bench_json(script, "fig3_sharded_error")
    if err:
        return [err]
    return [(f"fig3_sharded_{engine}_8shard", d["us"],
             f"pairs={d['pairs']}_nshards={d['n_shards']}"
             f"_maxshardpad={d['maxshardpad']:.2f}")
            for engine, d in data.items()]


def fig4_streaming():
    """Split-streaming executor rows (the Hadoop behaviors themselves, not a
    single paper exhibit): an out-of-core catalog 8x the per-split size
    streamed from a memmap file, map-side combine on vs off for wordcount
    (shuffle-byte and wall deltas), and the transfer/compute overlap
    fraction. Same warmup + best-of-3 convention as fig3."""
    import tempfile
    from repro.data import (ArraySplits, MemmapCatalogSplits, MemmapTokens,
                            TokenBlockSplits, sky)
    from repro.mapreduce import (neighbor_search_job, run_job,
                                 run_job_streaming, token_histogram_job)

    def best(fn, reps=3):
        fn()                                    # warmup (compile caches)
        return min((fn() for _ in range(reps)), key=lambda r: r.stats.wall_s)

    rows = []
    with tempfile.TemporaryDirectory() as d:
        # out-of-core neighbor search: a memmap catalog 8x the split size
        # streams split-by-split; the raw catalog is never whole on device
        # (only the accumulated int16 wire stream persists, at half size)
        xyz = sky.make_catalog(40000, 0)
        cat = os.path.join(d, "catalog.f32")
        MemmapCatalogSplits.write(cat, xyz)
        src = MemmapCatalogSplits(cat, d=3, rows_per_split=5000)
        job = neighbor_search_job(0.02, codec="int16", tile=256)
        res = best(lambda: run_job_streaming(job, src))
        st = res.stats
        mono = best(lambda: run_job(job, xyz))
        rows.append(("fig4_stream_outofcore_search_8x", st.wall_s * 1e6,
                     f"pairs={res.output}_nsplits={st.n_splits}"
                     f"_splitrows={src.rows_per_split}_totalrows={src.n_rows}"
                     f"_overlapfrac={st.overlap_fraction:.2f}"
                     f"_monolithic_us={mono.stats.wall_s * 1e6:.0f}"))
        assert res.output == mono.output, (res.output, mono.output)

        # out-of-core wordcount with map-side combine: only the combined
        # [vocab] accumulator persists across splits (O(vocab) device memory)
        vocab, seq, rows_per, n_splits = 2048, 1024, 16, 8
        tok = os.path.join(d, "tokens.bin")
        rng = np.random.default_rng(0)
        MemmapTokens.write(tok, rng.integers(0, vocab,
                                             (rows_per * n_splits, seq)))
        tsrc = TokenBlockSplits(MemmapTokens(tok, seq), seq_len=seq,
                                rows_per_split=rows_per, n_splits=n_splits)
        wjob = token_histogram_job(vocab, n_partitions=16, tile=256)
        on = best(lambda: run_job_streaming(wjob, tsrc))
        rows.append(("fig4_stream_outofcore_wordcount_8x",
                     on.stats.wall_s * 1e6,
                     f"tokens={rows_per * n_splits * seq}"
                     f"_nsplits={on.stats.n_splits}"
                     f"_combiner={on.stats.combiner}"
                     f"_overlapfrac={on.stats.overlap_fraction:.2f}"))

        # combiner on vs off: same source, wire bytes and wall side by side
        off = best(lambda: run_job_streaming(wjob, tsrc, combiner=None))
        ratio = off.stats.shuffle_wire_bytes / on.stats.shuffle_wire_bytes
        np.testing.assert_array_equal(on.output, off.output)
        rows.append(("fig4_stream_combiner_on", on.stats.wall_s * 1e6,
                     f"shuffleB={on.stats.shuffle_wire_bytes}"
                     f"_vs_off_ratio={ratio:.1f}"))
        rows.append(("fig4_stream_combiner_off", off.stats.wall_s * 1e6,
                     f"shuffleB={off.stats.shuffle_wire_bytes}"))
        assert ratio >= 2.0, f"combiner wire reduction below gate: {ratio}"

    # in-memory split streaming vs monolithic (executor overhead + overlap)
    xyz = sky.make_catalog(20000, 0)
    job = neighbor_search_job(0.02, codec="int16", tile=256)
    srun = best(lambda: run_job_streaming(job, ArraySplits(xyz, 4)))
    st = srun.stats
    exposed = st.fetch_wall_s
    rows.append(("fig4_stream_search_4split", st.wall_s * 1e6,
                 f"pairs={srun.output}_nsplits=4"
                 f"_overlapfrac={st.overlap_fraction:.2f}"
                 f"_exposedfetch_us={exposed * 1e6:.0f}"
                 f"_hidden_us={st.overlap_hidden_s * 1e6:.0f}"))
    return rows


def fig5_service():
    """MapReduce-as-a-service (the workload-consolidation argument): a
    resident sharded catalog answers a stream of small neighbor-search /
    statistics queries through the submit queue + admission window. Rows:
    the sequential run_job-per-query baseline (every query pays its own
    map+shuffle+reduce), the closed-loop batched service (gated >= 3x that
    baseline), and paced offered loads with p50/p99 latency — all steady
    state (warmup pass first, the ``_t`` convention)."""
    from repro.data import sky
    from repro.mapreduce import (ZonePartitioner, latency_summary,
                                 neighbor_search_job,
                                 neighbor_statistics_job, run_job)
    from repro.serving.mr_service import MRQueryService

    xyz = sky.make_catalog(20000, 0)
    R = 0.02
    part = ZonePartitioner(R)
    edges = np.linspace(R / 4, R, 4)
    distinct = [neighbor_search_job(r, partitioner=part, codec="int16",
                                    tile=256) for r in (R, R / 2, R / 4)]
    distinct.append(neighbor_statistics_job(edges / sky.ARCSEC,
                                            partitioner=part, codec="int16",
                                            tile=256))
    n_req = 32
    mix = [distinct[i % len(distinct)] for i in range(n_req)]

    # sequential baseline: one full map+shuffle+reduce per query
    for j in distinct:
        run_job(j, xyz)                        # warmup (compile caches)
    t0 = time.perf_counter()
    seq_out = [run_job(j, xyz).output for j in mix]
    seq_s = time.perf_counter() - t0
    rows = [("fig5_service_sequential", seq_s / n_req * 1e6,
             f"nreq={n_req}_ndistinct={len(distinct)}"
             f"_qps={n_req / seq_s:.1f}")]

    svc = MRQueryService(max_batch=16, max_wait_s=0.002)
    t0 = time.perf_counter()
    svc.load_catalog("sky", xyz, part, codec="int16", tile=256)
    load_s = time.perf_counter() - t0

    def burst():
        reqs = [svc.submit(j, catalog="sky") for j in mix]
        svc.run_pending()
        return [r.output for r in reqs]

    outs = burst()                             # warmup
    for got, want in zip(outs, seq_out):       # service == per-query runs
        np.testing.assert_array_equal(got, want)
    svc.request_stats.clear()
    svc.batches.clear()
    t0 = time.perf_counter()
    burst()
    svc_s = time.perf_counter() - t0
    s = latency_summary(svc.request_stats)
    speedup = seq_s / svc_s
    rows.append(("fig5_service_batched", svc_s / n_req * 1e6,
                 f"qps={n_req / svc_s:.0f}_speedup={speedup:.1f}x"
                 f"_p50ms={s['p50_ms']:.1f}_p99ms={s['p99_ms']:.1f}"
                 f"_meanbatch={s['mean_batch']:.1f}"
                 f"_shuffleonce_s={load_s:.2f}"))
    assert speedup >= 3.0, \
        f"batched service below 3x-vs-sequential gate: {speedup:.2f}x"

    # offered-load sweep: pace arrivals at fractions of burst capacity
    # through the background admission thread; latency vs throughput
    cap_qps = n_req / svc_s
    svc.start()
    for label, frac in (("0.5x", 0.5), ("1x", 1.0), ("2x", 2.0)):
        svc.request_stats.clear()
        offered = cap_qps * frac
        gap = 1.0 / offered
        t0 = time.perf_counter()
        reqs = []
        for i, j in enumerate(mix):
            target = t0 + i * gap
            now = time.perf_counter()
            if target > now:
                time.sleep(target - now)
            reqs.append(svc.submit(j, catalog="sky"))
        for r in reqs:
            r.result(timeout=300)
        wall = time.perf_counter() - t0
        s = latency_summary(svc.request_stats)
        rows.append((f"fig5_service_load{label}", wall / n_req * 1e6,
                     f"offered_qps={offered:.0f}"
                     f"_achieved_qps={s['qps']:.0f}"
                     f"_p50ms={s['p50_ms']:.1f}_p99ms={s['p99_ms']:.1f}"
                     f"_waitp99ms={s['wait_p99_ms']:.1f}"
                     f"_meanbatch={s['mean_batch']:.1f}"))
    svc.close()
    return rows


def fig6_speculation():
    """Speculative re-execution recovering an injected straggler (Hadoop's
    speculative-task claim, measured end to end on the lane scheduler).
    Three runs of the same 8-split neighbor search on 4 concurrent lanes:
    clean (no fault), a straggler split whose first fetch stalls ~3x the
    clean wall with speculation OFF (the stall is served out), and the same
    straggler with speculation ON (the slow attempt is cloned onto a free
    lane, the clone's fast re-fetch wins, the stalled original is cancelled
    mid-sleep). Gates: without speculation the straggler costs >= 2x the
    clean wall; with it the wall lands within 1.3x clean, recovering >= 70%%
    of the injected slowdown — and all three runs are bit-identical."""
    from repro.data import ArraySplits, sky
    from repro.ft import FaultySplitSource, SpeculativeConfig
    from repro.mapreduce import neighbor_search_job, run_job_streaming

    xyz = sky.make_catalog(20000, 0)
    job = neighbor_search_job(0.02, codec="int16", tile=256)
    n_splits, n_lanes = 8, 4
    spec_cfg = SpeculativeConfig(slowdown=1.5, min_finished=2, max_clones=1)

    def lanes_run(src, speculate=None):
        return run_job_streaming(job, src, n_lanes=n_lanes,
                                 speculate=speculate)

    def clean_src():
        return ArraySplits(xyz, n_splits)

    lanes_run(clean_src())                      # warmup (compile caches)
    clean = min((lanes_run(clean_src()) for _ in range(2)),
                key=lambda r: r.stats.elapsed_s)
    t_clean = clean.stats.elapsed_s
    rows = [("fig6_spec_nostraggler", t_clean * 1e6,
             f"pairs={clean.output}_nsplits={n_splits}_nlanes={n_lanes}")]

    delay = 3.0 * t_clean                       # the injected straggler

    def straggler_src():
        return FaultySplitSource(clean_src(), delays={0: delay})

    # speculation OFF: the stalled fetch is served out in full
    nospec = lanes_run(straggler_src())
    t_nospec = nospec.stats.elapsed_s
    rows.append(("fig6_spec_straggler_nospec", t_nospec * 1e6,
                 f"delay_s={delay:.2f}_slowdown={t_nospec / t_clean:.1f}x"))

    # speculation ON: clone wins, stalled original cancelled mid-sleep
    spec = min((lanes_run(straggler_src(), speculate=spec_cfg)
                for _ in range(2)), key=lambda r: r.stats.elapsed_s)
    t_spec = spec.stats.elapsed_s
    recovered = (t_nospec - t_spec) / (t_nospec - t_clean)
    rows.append(("fig6_spec_straggler_spec", t_spec * 1e6,
                 f"speculated={spec.stats.speculated}"
                 f"_clonewins={spec.stats.clone_wins}"
                 f"_vs_clean={t_spec / t_clean:.2f}x"
                 f"_recovered={recovered:.2f}"))

    assert clean.output == nospec.output == spec.output   # bit parity
    assert spec.stats.speculated >= 1 and spec.stats.clone_wins >= 1
    assert t_nospec >= 2.0 * t_clean, \
        f"injected straggler too cheap: {t_nospec / t_clean:.2f}x clean"
    assert t_spec <= 1.3 * t_clean, \
        f"speculation failed to recover: {t_spec / t_clean:.2f}x clean"
    assert recovered >= 0.7, \
        f"recovered only {recovered:.0%} of the injected slowdown"
    return rows


def table3_apps():
    """App runtimes vs radius (the paper's theta sweep) through the Job API,
    with the per-job Amdahl numbers the paper's Table 4 derives per task —
    plus the batched search+stats pass and the wordcount job. Steady state:
    each row runs once for warmup (compile caches) and reports the second
    run, the ``_t`` convention."""
    from repro.data import sky
    from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                                 neighbor_statistics_job, run_job, run_jobs,
                                 token_histogram)
    xyz = sky.make_catalog(20000, 1)
    rows = []

    def steady(fn):
        fn()
        return fn()

    for radius, label in [(0.01, "15as_scaled"), (0.02, "30as_scaled"),
                          (0.04, "60as_scaled")]:
        res = steady(lambda: run_job(neighbor_search_job(radius, tile=256),
                                     xyz))
        am = res.stats.roofline().amdahl_numbers()
        rows.append((f"table3_search_{label}", res.stats.wall_s * 1e6,
                     f"pairs={res.output}_AD={am['AD']:.2g}"))
    edges = np.linspace(0.005, 0.04, 8)
    res = steady(lambda: run_job(neighbor_statistics_job(
        edges / sky.ARCSEC, tile=256), xyz))
    rows.append(("table3_stats", res.stats.wall_s * 1e6,
                 f"pairs_total={int(res.output.sum())}"))
    # both apps batched over ONE shuffle (the Job API's multi-job batching)
    part = ZonePartitioner(float(edges[-1]))
    batched = steady(lambda: run_jobs(
        [neighbor_search_job(float(edges[-1]), partitioner=part, tile=256),
         neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                 tile=256)], xyz))
    rows.append(("table3_search+stats_batched", batched[0].stats.wall_s * 1e6,
                 f"pairs={batched[0].output}"))
    # non-astronomy workload on the same engine (Hadoop's wordcount)
    from repro.data import SyntheticTokens
    toks = SyntheticTokens(50000, 0).block(0, 64, 1024)
    res = steady(lambda: token_histogram(toks, 50000, n_partitions=16))
    rows.append(("table3_wordcount_64x1024", res.stats.wall_s * 1e6,
                 f"tokens={toks.size}_top={int(res.output.max())}"
                 f"_domstage={res.stats.dominant_stage}"))
    rows += _table3_sharded()
    return rows


def _table3_sharded():
    """The batched search+stats pass on an 8-shard data mesh through the
    sharded device engine (subprocess, 8 forced host devices) — the
    multi-node analogue of the paper's per-app runtime rows."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, %r)
import json
import numpy as np
from repro.core.compat import make_mesh
from repro.data import sky
from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                             neighbor_statistics_job, run_jobs)

mesh = make_mesh((8,), ("data",))
xyz = sky.make_catalog(20000, 1)
edges = np.linspace(0.005, 0.04, 8)
part = ZonePartitioner(float(edges[-1]))
jobs = [neighbor_search_job(float(edges[-1]), partitioner=part, tile=256),
        neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                tile=256)]
run_jobs(jobs, xyz, mesh=mesh, engine="device")            # warmup
rs = run_jobs(jobs, xyz, mesh=mesh, engine="device")
print(json.dumps({"us": rs[0].stats.wall_s * 1e6,
                  "pairs": int(rs[0].output),
                  "n_shards": rs[0].stats.n_shards}))
""" % (os.path.join(ROOT, "src"),)
    d, err = _subprocess_bench_json(script, "table3_sharded_error")
    if err:
        return [err]
    return [("table3_search+stats_sharded_8shard", d["us"],
             f"pairs={d['pairs']}_nshards={d['n_shards']}_engine=device")]


def table4_amdahl():
    """Balance (Amdahl) table: per-JOB rows from MapReduce ``StageStats``
    (always available — the paper derives Amdahl numbers per Hadoop task)
    side by side with per-ARCH rows from the dry-run artifacts when
    ``repro.launch.dryrun`` has produced them."""
    rows = []
    # per-job Amdahl numbers straight from StageStats.roofline()
    from repro.data import sky
    from repro.mapreduce import (neighbor_search_job, neighbor_statistics_job,
                                 run_job, token_histogram)
    xyz = sky.make_catalog(8000, 0)
    jobs = {
        "search": lambda: run_job(neighbor_search_job(0.02, codec="int16"),
                                  xyz),
        "stats": lambda: run_job(neighbor_statistics_job(
            np.linspace(0.005, 0.02, 8) / sky.ARCSEC), xyz),
        "wordcount": lambda: token_histogram(
            np.random.default_rng(0).integers(0, 30000, 1 << 15), 30000),
    }
    for name, fn in jobs.items():
        fn()                                   # warmup (compile caches)
        st = fn().stats
        am = st.roofline().amdahl_numbers()
        rows.append((f"table4_job_{name}", st.wall_s * 1e6,
                     f"AD={am['AD']:.2g}_ADN={am['ADN']:.2g}"
                     f"_dom={st.dominant_stage}_engine={st.engine}"))
    # per-arch rows from dry-run artifacts (when they exist)
    art = os.path.join(ROOT, "artifacts", "dryrun")
    if not os.path.isdir(art):
        rows.append(("table4_archs_missing", 0.0,
                     "run repro.launch.dryrun for per-arch rows"))
        return rows
    for fn in sorted(os.listdir(art)):
        if not fn.endswith("__16x16__baseline.json") or "train_4k" not in fn:
            continue
        rec = json.load(open(os.path.join(art, fn)))
        if rec.get("status") != "ok":
            continue
        t = rec["terms"]
        rows.append((f"table4_{rec['arch']}", t["step_time_s"] * 1e6,
                     f"AD={t['AD']:.2f}_ADN={t['ADN']:.2f}"
                     f"_dom={t['dominant']}"
                     f"_useful={t['useful_flop_ratio']:.2f}"
                     f"_chips_bal={t['chips_to_balance']:.0f}"))
    return rows


def fig7_spill():
    """External shuffle spill tier (Hadoop's map-side spill-to-disk, the
    paper's memory-for-disk trade on low-power nodes): a pair job whose
    accumulated wire streams exceed a spill budget set to 1/4 of the
    spill-off accumulation, so the job can only complete out of core.
    Rows: spill OFF (today's accumulate path), spill ON at budget/4, and
    spill-everything (budget=0, the fully synchronous floor). Gates
    (asserted here, not just reported): all runs bit-identical to the
    monolithic oracle, and measured peak resident wire bytes <= budget +
    one spill chunk."""
    import tempfile
    from repro.data import MemmapCatalogSplits, sky
    from repro.mapreduce import (SpillConfig, neighbor_search_job, run_job,
                                 run_job_streaming)

    def best(fn, reps=3):
        fn()                                    # warmup (compile caches)
        return min((fn() for _ in range(reps)), key=lambda r: r.stats.wall_s)

    rows = []
    with tempfile.TemporaryDirectory() as d:
        xyz = sky.make_catalog(48000, 0)
        cat = os.path.join(d, "catalog.f32")
        MemmapCatalogSplits.write(cat, xyz)
        src = MemmapCatalogSplits(cat, d=3, rows_per_split=6000)
        job = neighbor_search_job(0.02, codec="int16", tile=256)

        mono = run_job(job, xyz)
        off = best(lambda: run_job_streaming(job, src))
        assert off.output == mono.output, (off.output, mono.output)
        rows.append(("fig7_spill_off", off.stats.wall_s * 1e6,
                     f"pairs={off.output}_nsplits={off.stats.n_splits}"
                     f"_wireB={off.stats.shuffle_wire_bytes}"))

        # budget = 1/4 of the spill-off wire accumulation: the run CANNOT
        # hold its streams resident — completing at all is the claim
        budget = off.stats.shuffle_wire_bytes // 4
        on = best(lambda: run_job_streaming(
            job, src, spill=SpillConfig(budget_bytes=budget,
                                        dir=os.path.join(d, "sp"))))
        st = on.stats
        assert on.output == mono.output, (on.output, mono.output)
        assert st.spilled_splits == st.n_splits, st.spilled_splits
        assert st.spill_peak_bytes <= budget + st.spill_chunk_bytes, \
            (st.spill_peak_bytes, budget, st.spill_chunk_bytes)
        rows.append(("fig7_spill_on_quarter", st.wall_s * 1e6,
                     f"pairs={on.output}_budgetB={budget}"
                     f"_spillB={st.spill_bytes}"
                     f"_peakB={st.spill_peak_bytes}"
                     f"_chunkB={st.spill_chunk_bytes}"
                     f"_ranges={st.spill_ranges}"
                     f"_spilled={st.spilled_splits}"
                     f"_spillwall_us={st.spill_wall_s * 1e6:.0f}"))

        # budget=0: every split spills synchronously — the out-of-core floor
        zero = best(lambda: run_job_streaming(
            job, src, spill=SpillConfig(budget_bytes=0,
                                        dir=os.path.join(d, "sp0"))), reps=2)
        zst = zero.stats
        assert zero.output == mono.output, (zero.output, mono.output)
        assert zst.spill_peak_bytes <= zst.spill_chunk_bytes, \
            (zst.spill_peak_bytes, zst.spill_chunk_bytes)
        rows.append(("fig7_spill_everything", zst.wall_s * 1e6,
                     f"pairs={zero.output}_spillB={zst.spill_bytes}"
                     f"_peakB={zst.spill_peak_bytes}"
                     f"_ranges={zst.spill_ranges}"
                     f"_vs_off_wall={zst.wall_s / off.stats.wall_s:.2f}x"))
    return rows


def fig8_autoplan():
    """Cost-model auto planning (``tile="auto"``/``codec="auto"``) vs the
    hand-tuned fig3/table3 configurations, paired rows per workload. Bit
    identity auto == hand is asserted internally (auto only moves shapes,
    never arithmetic); the auto >= hand TIMING gate lives in
    ``scripts/bench_diff.py --auto-gate`` over these rows, with the
    skewed-catalog pair required to be strictly faster — the workload where
    hand-tuned ``tile=256`` pays every small zone's padding and the
    predicted-wall planner does not. ``prederr`` in the derived field is
    ``StageStats.prediction_error`` (worst predicted-vs-actual stage-wall
    ratio; analytic-defaults backends are expected to be loose — the <=2x
    bound is a calibrated-backend property)."""
    from repro.data import SyntheticTokens, sky
    from repro.mapreduce import (neighbor_search_job, neighbor_statistics_job,
                                 run_job, token_histogram_job)
    rows = []

    def bench_pair(suffix, hand_job, auto_job, items, eq):
        res = {}
        for kind, job in (("hand", hand_job), ("auto", auto_job)):
            run_job(job, items)                  # warmup (compile caches)
            r = min((run_job(job, items) for _ in range(5)),
                    key=lambda r: r.stats.wall_s)
            res[kind] = r
            st = r.stats
            rows.append((f"fig8_{kind}_{suffix}", st.wall_s * 1e6,
                         f"tile={st.auto_tile or job.tile}"
                         f"_codec={st.codec}"
                         f"_padratio={st.reduce_padded_ratio:.2f}"
                         f"_prederr={st.prediction_error:.2f}"))
        assert eq(res["auto"].output, res["hand"].output), (
            suffix, res["auto"].output, res["hand"].output)
        return res

    # fig3-equivalent rows: the hand configs are fig3/table3's tuned picks
    xyz = sky.make_catalog(20000, 0)
    radius = 0.02
    bench_pair("search",
               neighbor_search_job(radius, tile=64),
               neighbor_search_job(radius, tile="auto", codec="auto"),
               xyz, lambda a, b: int(a) == int(b))
    edges = np.linspace(0.005, 0.04, 8)
    bench_pair("stats",
               neighbor_statistics_job(edges / sky.ARCSEC, tile=256),
               neighbor_statistics_job(edges / sky.ARCSEC, tile="auto",
                                       codec="auto"),
               xyz, lambda a, b: np.array_equal(a, b))
    toks = SyntheticTokens(50000, 0).block(0, 64, 1024)
    bench_pair("wordcount",
               token_histogram_job(50000, n_partitions=16, tile=256),
               token_histogram_job(50000, n_partitions=16, tile="auto",
                                   codec="auto"),
               toks.reshape(-1), lambda a, b: np.array_equal(a, b))

    # skewed catalog: 60% of the tokens come from a 50-token hot set that
    # hashes into a handful of giant partitions; the rest spread uniformly.
    # tile=256 (the fig3 "bigger blocks" hand pick) pads every small
    # partition toward the giants' quantum, and wordcount's bincount reduce
    # pays that padding DIRECTLY (no z-gap pruning rescues it like the
    # blocked pair engine does) — the rows-basis predicted-wall planner
    # must win outright here, not just tie.
    srng = np.random.default_rng(5)
    nskew = 120_000
    hot = srng.integers(0, 50, int(nskew * 0.6))
    cold = srng.integers(0, 50000, nskew - len(hot))
    skew_toks = srng.permutation(np.concatenate([hot, cold]))
    pair = bench_pair("skew",
                      token_histogram_job(50000, n_partitions=16, tile=256),
                      token_histogram_job(50000, n_partitions=16,
                                          tile="auto", codec="auto"),
                      skew_toks, lambda a, b: np.array_equal(a, b))
    assert pair["auto"].stats.wall_s < pair["hand"].stats.wall_s, (
        "auto planning must beat hand tile=256 on the skewed catalog",
        pair["auto"].stats.wall_s, pair["hand"].stats.wall_s)
    return rows


def fig9_energy():
    """The paper's headline (Fig. 9): energy-efficiency ratios of the
    low-power node vs the blade, split by workload class. The paper gets
    7.7x for data-intensive jobs but only 3.4x for compute-intensive ones
    — efficiency gains concentrate where the CPU mostly waits on I/O. We
    recast host-engine (numpy oracle, Atom-class profile: the CPU pays
    for every byte moved) vs device-engine (wire-dtype tiered shuffle,
    blade-class profile: I/O is cheap, compute draws the power) under the
    ``ModeledMeter``: per-stage-class watts x measured stage walls. The
    ORDERING is the reproduced claim (data-intensive ratio > compute-
    intensive ratio > 1), not the paper's absolute magnitudes — those
    depend on 2009-era Atom vs Xeon silicon we are not modeling. The
    balance-point row prices ``chips_to_balance`` in watts via the
    power-aware roofline term (the paper's 'four Atom cores' answer,
    asked as a wattage)."""
    from repro.data import sky
    from repro.mapreduce import (neighbor_search_job, neighbor_statistics_job,
                                 run_job)
    from repro.obs.energy import BLADE_DEVICE, ModeledMeter, use_meter

    xyz = sky.make_catalog(20000, 0)
    edges = np.linspace(0.005, 0.04, 8)
    workloads = [
        # search: one scalar per pair-block — shuffle/wire dominated
        ("search", neighbor_search_job(0.02, codec="int16", tile=256)),
        # stats: 8-bin histogram per block — reduce/compute dominated
        ("stats", neighbor_statistics_job(edges / sky.ARCSEC, codec="int16",
                                          tile=256)),
    ]
    rows, eff = [], {}
    with use_meter(ModeledMeter()):
        for wname, job in workloads:
            for engine in ("host", "device"):
                run_job(job, xyz, engine=engine)     # warmup (compile caches)
                r = min((run_job(job, xyz, engine=engine) for _ in range(3)),
                        key=lambda r: r.stats.wall_s)
                st = r.stats
                assert st.energy_j > 0.0, (wname, engine, st.energy_j)
                eff[(wname, engine)] = st
                rows.append((f"fig9_energy_{wname}_{engine}",
                             st.wall_s * 1e6,
                             f"energyJ={st.energy_j:.3f}"
                             f"_rowsperJ={st.rows_per_joule:.0f}"
                             f"_source={st.energy_source}"
                             f"_dominant={st.dominant_stage}"))

    def ratio(wname):
        return (eff[(wname, "device")].rows_per_joule
                / eff[(wname, "host")].rows_per_joule)

    r_data, r_comp = ratio("search"), ratio("stats")
    # the reproduced ordering: data-intensive efficiency gain exceeds the
    # compute-intensive one, both > 1 (paper: 7.7x vs 3.4x)
    assert r_data > r_comp > 1.0, (r_data, r_comp)
    st = eff[("search", "device")]
    terms = st.roofline(chip_w=BLADE_DEVICE.compute_w)
    rows.append(("fig9_energy_ratios", 0.0,
                 f"data_ratio={r_data:.2f}x_compute_ratio={r_comp:.2f}x"
                 f"_paper=7.7x/3.4x"
                 f"_balance_chips={terms.chips_to_balance():.3f}"
                 f"_balance_w={terms.balance_watts():.1f}"))
    return rows


ALL = [fig1_direct_io, table2_network, fig2_pipeline, fig3_improvements,
       fig4_streaming, fig5_service, fig6_speculation, fig7_spill,
       fig8_autoplan, fig9_energy, table3_apps, table4_amdahl]
