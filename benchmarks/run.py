# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):   # `python benchmarks/run.py`
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    from benchmarks.paper_benches import ALL
    print("name,us_per_call,derived")
    failures = 0
    for bench in ALL:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the harness going; report the failure
            failures += 1
            print(f"{bench.__name__}_ERROR,0.0,{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
