# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys


def main() -> None:
    from benchmarks.paper_benches import ALL
    print("name,us_per_call,derived")
    failures = 0
    for bench in ALL:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the harness going; report the failure
            failures += 1
            print(f"{bench.__name__}_ERROR,0.0,{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
