"""Serve a small model with batched requests (continuous slot batching).

    PYTHONPATH=src python examples/serve_lm.py --requests 16 --slots 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import RunConfig, get_arch
from repro.launch.mesh import make_cpu_mesh
from repro.models import model as mdl
from repro.parallel.sharding import make_rules, use_mesh
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    rc = RunConfig(remat="none")
    mesh = make_cpu_mesh()
    with use_mesh(mesh, make_rules(mesh)):
        params, biases = mdl.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, rc, params, biases, mesh, slots=args.slots,
                      max_len=256)
    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(args.requests):
        r = Request(rid=rid,
                    prompt=rng.integers(0, cfg.vocab, rng.integers(4, 16)).tolist(),
                    max_new=args.max_new)
        reqs.append(r)
        eng.submit(r)
    t0 = time.time()
    steps = eng.run(max_steps=250)
    dt = time.time() - t0
    finished = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"[serve] {finished}/{args.requests} requests, {toks} tokens, "
          f"{steps} steps in {dt:.1f}s -> {toks/dt:.1f} tok/s "
          f"(slot util {toks/max(steps*args.slots,1):.0%})")


if __name__ == "__main__":
    main()
