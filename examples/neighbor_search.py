"""The paper's applications end-to-end on a synthetic sky catalog, through
the composable Job API.

Neighbor Searching (data-intensive) + Neighbor Statistics (compute-intensive)
are built from pluggable stages — ``ZonePartitioner`` (map), a registered
``ShuffleCodec`` (shuffle), and pair-kernel reducers — and run by one engine,
which also batches both apps over a single shuffle. Every run prints its
``StageStats`` and the per-job Amdahl numbers (the paper's Table-4 analysis).

The streaming section runs the same job out-of-core: the catalog lives in a
memmap file and crosses the engine split-by-split (HDFS-block analogues)
with the next split's read + transfer double-buffered under the current
split's compute — same answer, bounded memory, and the exposed-vs-hidden
I/O split printed from ``StageStats``.

The speculation section injects a straggler (one split's fetch stalls 3x
the clean wall) and shows the lane scheduler recover it: the slow attempt
is cloned onto a free lane, the clone wins, the stalled original is
cancelled — same answer, a fraction of the stall paid.

The last section flips the execution model from batch to SERVICE: the
catalog is shuffled once into a device-resident ``ResidentCatalog`` and a
stream of small queries goes through ``MRQueryService``'s submit queue —
micro-batched, coalesced, each answered by a pure fused reduce — with
qps / p50 / p99 from the per-request ``RequestStats``.

    PYTHONPATH=src python examples/neighbor_search.py [--n 50000]
"""
import argparse
import os
import tempfile

import numpy as np

from repro.data import MemmapCatalogSplits, sky
from repro.mapreduce import (ZonePartitioner, available_codecs,
                             neighbor_search_job, neighbor_statistics_job,
                             run_job, run_job_streaming, run_jobs)


def show(res, label):
    st = res.stats
    am = st.roofline().amdahl_numbers()
    print(f"  {label}: {st.wall_s:.2f}s "
          f"(map {st.map_wall_s:.2f} / shuffle {st.shuffle_wall_s:.2f} "
          f"/ reduce {st.reduce_wall_s:.2f}; dominant={st.dominant_stage}) "
          f"shuffle={st.shuffle_wire_bytes / 1e6:.1f}MB "
          f"x{st.compression_ratio:.1f} AD={am['AD']:.2g}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50000)
    ap.add_argument("--radius", type=float, default=0.02)
    args = ap.parse_args()

    print(f"== synthetic catalog: {args.n} objects ==")
    xyz = sky.make_catalog(args.n, seed=0)

    print("-- Neighbor Searching (radius sweep, cf. paper Table 3) --")
    for radius in (args.radius / 2, args.radius, args.radius * 2):
        res = run_job(neighbor_search_job(radius, tile=256), xyz)
        print(f"  radius={radius:.3f} rad: {res.output} pairs in "
              f"{res.stats.wall_s:.2f}s")

    print(f"-- stage swaps (cf. Figure 3; codecs: {available_codecs()}) --")
    for label, kw in {
        "baseline": dict(tile=64),
        "batched (buffering analogue)": dict(tile=512),
        "int16 shuffle (LZO analogue)": dict(tile=512, codec="int16"),
        # int8's ~1/127 coordinate step is coarse for radii this small: max
        # compression, visible count error — the LZO trade taken too far
        "int8 shuffle (block-quantized)": dict(tile=512, codec="int8"),
    }.items():
        res = run_job(neighbor_search_job(args.radius, **kw), xyz)
        show(res, f"{label}: pairs={res.output}")

    print("-- both apps batched over ONE shuffle (cf. paper section 2.2) --")
    edges = np.linspace(args.radius / 8, args.radius, 8)
    part = ZonePartitioner(args.radius)
    search, stats = run_jobs(
        [neighbor_search_job(args.radius, partitioner=part, tile=256),
         neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                 tile=256)], xyz)
    print(f"  pairs={search.output}, histogram={stats.output.tolist()}")
    show(search, "batched search+stats")

    print("-- out-of-core: the same job streamed from a memmap catalog --")
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "catalog.f32")
        MemmapCatalogSplits.write(path, xyz)        # stand-in for a big file
        src = MemmapCatalogSplits(path, d=3,
                                  rows_per_split=max(args.n // 8, 1))
        res = run_job_streaming(
            neighbor_search_job(args.radius, codec="int16", tile=256), src)
        st = res.stats
        print(f"  pairs={res.output} over {st.n_splits} splits "
              f"(per-split rows<={src.rows_per_split}); split I/O: "
              f"{st.overlap_hidden_s:.3f}s hidden under compute, "
              f"{st.fetch_wall_s:.3f}s exposed "
              f"(overlap={st.overlap_fraction:.0%})")

    print("-- speculative re-execution: an injected straggler recovered --")
    from repro.ft import FaultySplitSource, SpeculativeConfig
    from repro.data import ArraySplits
    clean = run_job_streaming(
        neighbor_search_job(args.radius, codec="int16", tile=256),
        ArraySplits(xyz, 8), n_lanes=4)
    t_clean = clean.stats.elapsed_s
    # split 0's first fetch stalls 3x the clean wall (a dying-disk analogue);
    # the policy clones it onto a free lane, the clone's fast re-fetch wins,
    # and the stalled original is cancelled mid-sleep
    slow = FaultySplitSource(ArraySplits(xyz, 8), delays={0: 3.0 * t_clean})
    spec = run_job_streaming(
        neighbor_search_job(args.radius, codec="int16", tile=256), slow,
        n_lanes=4, speculate=SpeculativeConfig(slowdown=1.5, min_finished=2))
    st = spec.stats
    print(f"  clean: {t_clean:.2f}s on {clean.stats.n_lanes} lanes; "
          f"straggler(+{3.0 * t_clean:.2f}s) with speculation: "
          f"{st.elapsed_s:.2f}s ({st.elapsed_s / t_clean:.2f}x clean; "
          f"speculated={st.speculated}, clone_wins={st.clone_wins})")
    assert spec.output == clean.output        # recovery is bit-identical

    print("-- service mode: resident catalog, micro-batched queries --")
    from repro.serving import MRQueryService
    svc = MRQueryService(max_batch=8, max_wait_s=0.002)
    cat = svc.load_catalog("sky", xyz, part, codec="int16", tile=256)
    print(f"  shuffled once: {cat.nbytes / 1e6:.1f}MB resident wire bytes, "
          f"{cat.P} partitions")
    with svc:                    # background admission/serving thread
        reqs = [svc.submit(neighbor_search_job(r, partitioner=part,
                                               codec="int16", tile=256),
                           catalog="sky")
                for r in (args.radius, args.radius / 2) * 4]
        outs = [r.result(timeout=600) for r in reqs]
    s = svc.latency_summary()
    print(f"  {s['n']} queries at {s['qps']:.0f} qps "
          f"(p50 {s['p50_ms']:.1f}ms / p99 {s['p99_ms']:.1f}ms, "
          f"mean batch {s['mean_batch']:.1f}); "
          f"pairs@radius={outs[0]}, pairs@radius/2={outs[1]}")


if __name__ == "__main__":
    main()
