"""The paper's applications end-to-end on a synthetic sky catalog.

Neighbor Searching (data-intensive) + Neighbor Statistics (compute-intensive),
with the three paper optimizations toggled (buffering/batching, compression).

    PYTHONPATH=src python examples/neighbor_search.py [--n 50000]
"""
import argparse
import time

import numpy as np

from repro.data import sky
from repro.mapreduce import (bucket_by_zone, neighbor_search_count,
                             neighbor_statistics)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50000)
    ap.add_argument("--radius", type=float, default=0.02)
    args = ap.parse_args()

    print(f"== synthetic catalog: {args.n} objects ==")
    xyz = sky.make_catalog(args.n, seed=0)

    print("-- Neighbor Searching (radius sweep, cf. paper Table 3) --")
    for radius in (args.radius / 2, args.radius, args.radius * 2):
        t0 = time.perf_counter()
        count = neighbor_search_count(xyz, radius, tile=256)
        dt = time.perf_counter() - t0
        print(f"  radius={radius:.3f} rad: {count} pairs in {dt:.2f}s")

    print("-- paper optimizations (cf. Figure 3) --")
    for name, kw in {
        "baseline": dict(tile=64),
        "batched (buffering analogue)": dict(tile=512),
        "compressed shuffle (LZO analogue)": dict(tile=512,
                                                  compress_coords=True),
    }.items():
        t0 = time.perf_counter()
        count = neighbor_search_count(xyz, args.radius, **kw)
        dt = time.perf_counter() - t0
        zd = bucket_by_zone(xyz, args.radius, **kw)
        print(f"  {name}: {dt:.2f}s, shuffle={zd.shuffle_bytes/1e6:.1f}MB, "
              f"pairs={count}")

    print("-- Neighbor Statistics (cf. paper section 2.2) --")
    edges = np.linspace(args.radius / 8, args.radius, 8)
    t0 = time.perf_counter()
    h = neighbor_statistics(xyz, edges_arcsec=edges / sky.ARCSEC, tile=256)
    print(f"  histogram in {time.perf_counter()-t0:.2f}s: {h.tolist()}")


if __name__ == "__main__":
    main()
