"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full substrate — pipeline, bucketed fused optimizer, checkpointing with
replication + checksums, straggler monitor. This is the (b)-deliverable driver; on a
CPU container a step takes a few seconds, so the default is 200 steps (override with
--steps 20 for a quick look).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

from repro.configs import RunConfig, get_arch
from repro.launch.mesh import make_cpu_mesh
from repro.launch.train import train


def lm_100m():
    """~100M-param llama-family config (a real small LM, not a smoke stub)."""
    base = get_arch("tinyllama-1.1b")
    return dataclasses.replace(
        base, name="lm-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=5, head_dim=64, d_ff=1792, vocab=32000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_lm100m_ckpt")
    args = ap.parse_args()

    cfg = lm_100m()
    from repro.models.model import count_params_total
    print(f"== {cfg.name}: {count_params_total(cfg)/1e6:.1f}M params ==")
    rc = RunConfig(arch=cfg.name, steps=args.steps,
                   warmup_steps=max(args.steps // 20, 1),
                   learning_rate=3e-4, remat="none", bucketed_updates=True)
    state, losses = train(cfg, rc, batch=args.batch, seq=args.seq,
                          steps=args.steps, ckpt_dir=args.ckpt,
                          ckpt_every=max(args.steps // 4, 10), log_every=10)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
