"""Quickstart: train a tiny llama-family model for 30 steps, then generate.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_arch
from repro.launch.mesh import make_cpu_mesh
from repro.launch.train import train
from repro.models import model as mdl
from repro.parallel.sharding import make_rules, use_mesh
from repro.serving.engine import Request, ServeEngine


def main():
    cfg = get_arch("tinyllama-1.1b").reduced()
    rc = RunConfig(remat="none", steps=30, warmup_steps=3, learning_rate=1e-3)
    mesh = make_cpu_mesh()
    print(f"== training {cfg.name} (reduced) for 30 steps ==")
    state, losses = train(cfg, rc, batch=8, seq=64, steps=30, mesh=mesh,
                          log_every=10)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("== generating with the serving engine ==")
    eng = ServeEngine(cfg, rc, state["params"], state["biases"], mesh,
                      slots=2, max_len=64)
    eng.submit(Request(rid=0, prompt=[1, 2, 3, 4], max_new=12))
    eng.submit(Request(rid=1, prompt=[5, 6, 7], max_new=12))
    reqs = list(eng.active)
    eng.run(max_steps=40)
    print("generation finished; engine processed both requests.")


if __name__ == "__main__":
    main()
