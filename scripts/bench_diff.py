#!/usr/bin/env python
"""Diff two BENCH_*.json snapshots and flag per-row regressions.

For every row name present in both snapshots, prints the ``us_per_call``
ratio (new/old); rows slower than ``--threshold`` (default 1.15x) are
flagged and make the script exit 1, so CI can gate on it:

    python scripts/bench_diff.py BENCH_pr1.json BENCH_pr2.json --prefix fig3

Rows with a zero/absent timing on either side (derived-only rows like
table2, rows that disappeared) are reported but never gate.

``--auto-gate FILE`` is a second mode: within ONE snapshot, every
``fig8_auto_<suffix>`` row is compared against its ``fig8_hand_<suffix>``
twin. Auto-planned configurations must be no slower than the hand-tuned
ones (auto/hand <= ``--auto-threshold``, default 1.10 for timing noise);
the ``skew`` row must be STRICTLY faster — that catalog is the case the
hand tile provably mis-sizes, so auto merely tying would mean the planner
learned nothing:

    python scripts/bench_diff.py --auto-gate BENCH_pr9.json
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        snap = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in snap["rows"]}


def diff(old: dict[str, float], new: dict[str, float], *, prefix: str = "",
         threshold: float = 1.15):
    """-> (report_lines, regressions) for rows matching ``prefix``."""
    names = [n for n in sorted(set(old) | set(new)) if n.startswith(prefix)]
    lines, regressions = [], []
    for name in names:
        o, n = old.get(name), new.get(name)
        if o is None or n is None:
            lines.append(f"{name:40s} {'added' if o is None else 'removed'}")
            continue
        if not o or not n:
            lines.append(f"{name:40s} untimed (old={o:.1f} new={n:.1f})")
            continue
        ratio = n / o
        flag = ""
        if ratio > threshold:
            flag = f"  REGRESSION (> {threshold:.2f}x)"
            regressions.append((name, ratio))
        lines.append(f"{name:40s} {o:12.1f} -> {n:12.1f} us"
                     f"  ({ratio:5.2f}x){flag}")
    return lines, regressions


def auto_gate(rows: dict[str, float], *, threshold: float = 1.10,
              strict_suffixes: tuple[str, ...] = ("skew",)):
    """-> (report_lines, violations) comparing fig8_auto_* vs fig8_hand_*."""
    suffixes = sorted(n[len("fig8_auto_"):] for n in rows
                      if n.startswith("fig8_auto_"))
    lines, violations = [], []
    if not suffixes:
        return ["no fig8_auto_* rows found"], [("fig8_auto_*", 0.0)]
    for s in suffixes:
        auto, hand = rows.get(f"fig8_auto_{s}"), rows.get(f"fig8_hand_{s}")
        if not auto or not hand:
            lines.append(f"fig8_{s:34s} missing hand twin")
            violations.append((f"fig8_{s}", 0.0))
            continue
        ratio = auto / hand
        strict = s in strict_suffixes
        bound = 1.0 if strict else threshold
        ok = ratio < bound if strict else ratio <= bound
        flag = "" if ok else (f"  AUTO SLOWER (need {'<' if strict else '<='}"
                              f" {bound:.2f}x)")
        if not ok:
            violations.append((f"fig8_{s}", ratio))
        lines.append(f"fig8_{s:10s} auto {auto:10.1f} vs hand {hand:10.1f} us"
                     f"  ({ratio:5.2f}x){'  [strict]' if strict else ''}"
                     f"{flag}")
    return lines, violations


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", nargs="?", help="baseline BENCH_*.json")
    ap.add_argument("new", nargs="?", help="candidate BENCH_*.json")
    ap.add_argument("--prefix", default="",
                    help="only compare rows whose name starts with this")
    ap.add_argument("--threshold", type=float, default=1.15,
                    help="flag rows slower than this new/old ratio")
    ap.add_argument("--auto-gate", metavar="FILE",
                    help="gate fig8 auto-vs-hand rows within one snapshot")
    ap.add_argument("--auto-threshold", type=float, default=1.10,
                    help="auto/hand ratio bound for non-strict fig8 rows")
    args = ap.parse_args()

    if args.auto_gate:
        lines, violations = auto_gate(load_rows(args.auto_gate),
                                      threshold=args.auto_threshold)
        print(f"auto-plan gate: {args.auto_gate}")
        for ln in lines:
            print("  " + ln)
        if violations:
            print(f"{len(violations)} auto-plan violation(s)")
            return 1
        print("auto plans hold up against hand tuning")
        return 0

    if not args.old or not args.new:
        ap.error("old and new snapshots are required unless --auto-gate")
    lines, regressions = diff(load_rows(args.old), load_rows(args.new),
                              prefix=args.prefix, threshold=args.threshold)
    print(f"bench diff: {args.old} -> {args.new}"
          + (f" (prefix={args.prefix!r})" if args.prefix else ""))
    for ln in lines:
        print("  " + ln)
    if regressions:
        worst = max(regressions, key=lambda r: r[1])
        print(f"{len(regressions)} regression(s); worst: "
              f"{worst[0]} at {worst[1]:.2f}x")
        return 1
    print("no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
