#!/usr/bin/env python
"""Diff two BENCH_*.json snapshots and flag per-row regressions.

For every row name present in both snapshots, prints the ``us_per_call``
ratio (new/old); rows slower than ``--threshold`` (default 1.15x) are
flagged and make the script exit 1, so CI can gate on it:

    python scripts/bench_diff.py BENCH_pr1.json BENCH_pr2.json --prefix fig3

Rows with a zero/absent timing on either side (derived-only rows like
table2, rows that disappeared) are reported but never gate.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        snap = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in snap["rows"]}


def diff(old: dict[str, float], new: dict[str, float], *, prefix: str = "",
         threshold: float = 1.15):
    """-> (report_lines, regressions) for rows matching ``prefix``."""
    names = [n for n in sorted(set(old) | set(new)) if n.startswith(prefix)]
    lines, regressions = [], []
    for name in names:
        o, n = old.get(name), new.get(name)
        if o is None or n is None:
            lines.append(f"{name:40s} {'added' if o is None else 'removed'}")
            continue
        if not o or not n:
            lines.append(f"{name:40s} untimed (old={o:.1f} new={n:.1f})")
            continue
        ratio = n / o
        flag = ""
        if ratio > threshold:
            flag = f"  REGRESSION (> {threshold:.2f}x)"
            regressions.append((name, ratio))
        lines.append(f"{name:40s} {o:12.1f} -> {n:12.1f} us"
                     f"  ({ratio:5.2f}x){flag}")
    return lines, regressions


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument("--prefix", default="",
                    help="only compare rows whose name starts with this")
    ap.add_argument("--threshold", type=float, default=1.15,
                    help="flag rows slower than this new/old ratio")
    args = ap.parse_args()
    lines, regressions = diff(load_rows(args.old), load_rows(args.new),
                              prefix=args.prefix, threshold=args.threshold)
    print(f"bench diff: {args.old} -> {args.new}"
          + (f" (prefix={args.prefix!r})" if args.prefix else ""))
    for ln in lines:
        print("  " + ln)
    if regressions:
        worst = max(regressions, key=lambda r: r[1])
        print(f"{len(regressions)} regression(s); worst: "
              f"{worst[0]} at {worst[1]:.2f}x")
        return 1
    print("no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
