"""Generate EXPERIMENTS.md sections from artifacts/dryrun JSONs."""
from __future__ import annotations

import json
import os
import sys

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")


def load(mesh: str, mode: str, tag: str = "") -> dict[str, dict]:
    out = {}
    for fn in sorted(os.listdir(ART)):
        if fn.endswith(f"__{mesh}__{mode}{tag}.json"):
            rec = json.load(open(os.path.join(ART, fn)))
            if "arch" not in rec:           # skip records carry only the cell name
                parts = rec.get("cell", fn).split("__")
                rec["arch"], rec["shape"] = parts[0], parts[1]
            out[f"{rec['arch']}|{rec['shape']}"] = rec
    return out


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}G"


def dryrun_table(mesh: str, mode: str = "baseline") -> str:
    rows = [f"| arch | shape | status | FLOPs (global) | HBM bytes | coll intra | "
            f"coll cross | mem/dev (arg+tmp) | compile s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key, r in load(mesh, mode).items():
        arch, shape = key.split("|")
        if r.get("status") == "skipped":
            rows.append(f"| {arch} | {shape} | skip | - | - | - | - | - | - |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {arch} | {shape} | ERROR | - | - | - | - | - | - |")
            continue
        t = r["terms"]
        m = r["memory"]
        mem = (m["argument_bytes_per_device"] or 0) + \
            (m["temp_bytes_per_device"] or 0)
        rows.append(
            f"| {arch} | {shape} | ok | {t['flops']:.2e} | "
            f"{t['hbm_bytes']:.2e} | {t['coll_bytes_intra']:.2e} | "
            f"{t['coll_bytes_cross']:.2e} | {mem/1e9:.1f}G | "
            f"{r['compile_s']:.0f} |")
    return "\n".join(rows)


def roofline_table(mode: str = "baseline") -> str:
    rows = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
            "MODEL_FLOPS | useful ratio | roofline frac | AD | ADN | note |",
            "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for key, r in load("16x16", mode).items():
        arch, shape = key.split("|")
        if r.get("status") != "ok":
            continue
        t = r["terms"]
        rows.append(
            f"| {arch} | {shape} | {t['t_compute_s']*1e3:.1f} | "
            f"{t['t_memory_s']*1e3:.1f} | {t['t_collective_s']*1e3:.1f} | "
            f"**{t['dominant']}** | {t['model_flops']:.2e} | "
            f"{t['useful_flop_ratio']:.2f} | {t['roofline_fraction']*100:.1f}% | "
            f"{t['AD']:.1f} | {t['ADN']:.1f} | {r['suggestion'][:60]} |")
    return "\n".join(rows)


if __name__ == "__main__":
    section = sys.argv[1] if len(sys.argv) > 1 else "all"
    if section in ("dryrun", "all"):
        print("### Single-pod (16x16 = 256 chips)\n")
        print(dryrun_table("16x16"))
        print("\n### Multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table("2x16x16"))
    if section in ("roofline", "all"):
        print("\n### Roofline (single-pod, baseline)\n")
        print(roofline_table())
