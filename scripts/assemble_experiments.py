"""Assemble the final EXPERIMENTS.md from the template + dry-run artifacts."""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "scripts"))

from make_experiments import dryrun_table, roofline_table, load  # noqa: E402


def val_numbers() -> dict[str, str]:
    art = os.path.join(ROOT, "artifacts", "dryrun")

    def g(name):
        p = os.path.join(art, name + ".json")
        return json.load(open(p)) if os.path.exists(p) else None

    out = {}
    # buckets (fig3/JNI): hcs0 vs hcs1 memory term
    h0 = g("tinyllama-1.1b__train_4k__16x16__baseline_hcs0_flatdp")
    h1 = g("tinyllama-1.1b__train_4k__16x16__baseline_hcs1_int8")
    if h0 and h1:
        m0 = h0["terms"]["t_memory_s"]
        m1 = h1["terms"]["t_memory_s"]
        out["VAL_BUCKETS"] = (
            f"memory term −{(1-m1/m0)*100:.0f}% (fused update, {m0*1e3:.0f}→"
            f"{m1*1e3:.0f} ms); op-count framing does not transfer under jit "
            f"(no per-op dispatch) — the byte framing does")
    # compression: granite a2a baseline vs int8-only
    b = g("granite-moe-3b-a800m__train_4k__16x16__baseline")
    c = g("granite-moe-3b-a800m__train_4k__16x16__baseline_hc5_int8only")
    if b and c:
        a0 = b["analyzer"]["coll_by_op"].get("all-to-all", 0)
        a1 = c["analyzer"]["coll_by_op"].get("all-to-all", 0)
        out["VAL_COMPRESS"] = (
            f"MoE a2a wire {a0:.2e}→{a1:.2e} B/dev (−{(1-a1/max(a0,1))*100:.0f}%); "
            f"grad-sync int8 only bites when the slow link dominates "
            f"(single-axis DP: confirmed; after hierarchical: moot — the paper's "
            f"repl-1 vs repl-3 result, replayed)")
    # hierarchical: C0 vs C1 cross-pod
    c0 = g("tinyllama-1.1b__train_4k__2x16x16__baseline_hc0_puredp")
    c1 = g("tinyllama-1.1b__train_4k__2x16x16__baseline_hc1_hier")
    if c0 and c1:
        x0 = c0["terms"]["coll_bytes_cross"]
        x1 = c1["terms"]["coll_bytes_cross"]
        out["VAL_HIER"] = (f"cross-pod bytes {x0:.2e}→{x1:.2e} "
                           f"(−{x0/max(x1,1):.1f}×) at 2 pods; scales with |data|")
    # donation: any optimized cell with alias bytes
    for fn in sorted(os.listdir(art)):
        if fn.endswith("__16x16__optimized.json"):
            r = json.load(open(os.path.join(art, fn)))
            if r.get("status") == "ok" and r["memory"]["alias_bytes_per_device"]:
                al = r["memory"]["alias_bytes_per_device"]
                outb = r["memory"]["output_bytes_per_device"]
                base = g(fn.replace("__optimized", "__baseline").split(".json")[0])
                extra = base["memory"]["output_bytes_per_device"] if base else 0
                out["VAL_DONATE"] = (
                    f"{al/1e9:.2f} GB/device aliased in place "
                    f"({r['arch']} {r['shape']}); baseline kept a separate "
                    f"{extra/1e9:.2f} GB output copy of the state")
                break
    out.setdefault("VAL_DONATE", "optimized cells alias the full state in place "
                                 "(alias_size == state size); baseline copies it")
    return out


def main():
    tpl = open(os.path.join(ROOT, "scripts", "EXPERIMENTS.template.md")).read()
    tables = ("### Single-pod (16×16 = 256 chips), baseline\n\n" +
              dryrun_table("16x16", "baseline") +
              "\n\n### Multi-pod (2×16×16 = 512 chips), baseline\n\n" +
              dryrun_table("2x16x16", "baseline"))
    opt = load("16x16", "optimized")
    if opt:
        tables += ("\n\n### Single-pod, optimized mode (beyond-paper config)\n\n" +
                   dryrun_table("16x16", "optimized"))
    mopt = load("2x16x16", "optimized")
    if mopt:
        tables += ("\n\n### Multi-pod, optimized mode"
                   " (recurrentgemma/mamba2/gemma2/internvl2/musicgen/starcoder2"
                   " run with bucketed_updates=false — the bucket reshard of"
                   " stacked scan params OOMs the CPU-host compile at 512 devices;"
                   " a TPU build chunks it)\n\n" +
                   dryrun_table("2x16x16", "optimized"))
    tpl = tpl.replace("<<DRYRUN_TABLES>>", tables)
    tpl = tpl.replace("<<ROOFLINE_TABLE>>", roofline_table("baseline"))
    perf = open(os.path.join(ROOT, "scripts", "perf_section.md")).read()
    tpl = tpl.replace("<<PERF_SECTION>>", perf)
    for k, v in val_numbers().items():
        tpl = tpl.replace(f"<<{k}>>", v)
    open(os.path.join(ROOT, "EXPERIMENTS.md"), "w").write(tpl)
    print("EXPERIMENTS.md assembled:",
          len(tpl.splitlines()), "lines")


if __name__ == "__main__":
    main()
