#!/usr/bin/env python
"""Run a traced lane-mode streaming job and export its Chrome trace.

Produces the artifact the observability layer promises: a lane-mode
streaming run (concurrent lanes + prefetch) recorded by ``obs.Tracer``
and saved as Chrome trace-event JSON — load it in Perfetto or
chrome://tracing to see map/shuffle/reduce stage spans, fetch-wait
stalls, and per-lane execution lanes with split/attempt ids.

    PYTHONPATH=src python scripts/export_trace.py [out.json]

Validates before writing: the run must stay bit-identical to the
monolithic oracle, every opened span must have closed, and the export
must contain the stage/lane span families — then prints the per-span
summary table. CI uploads the JSON as a build artifact.
"""
from __future__ import annotations

import json
import sys

from repro.data import sky
from repro.data.pipeline import ArraySplits
from repro.mapreduce import neighbor_search_job, run_job, run_job_streaming
from repro.obs import ModeledMeter, Tracer, use_meter, use_tracer

REQUIRED_SPANS = {"map", "shuffle", "reduce", "fetch-wait", "lane-exec",
                  "job"}


def main(out: str = "trace.json") -> int:
    xyz = sky.make_catalog(6000, 0)
    job = neighbor_search_job(0.02, codec="int16", tile=128)
    want = run_job(job, xyz)  # monolithic oracle + jit warmup
    with use_tracer(Tracer()) as tr, use_meter(ModeledMeter()):
        res = run_job_streaming(job, ArraySplits(xyz, n_splits=8),
                                n_lanes=3, prefetch=2)
    assert res.output == want.output, (res.output, want.output)
    assert tr.open_spans == 0, f"{tr.open_spans} spans left open"

    doc = json.loads(tr.export_json())          # round-trips as valid JSON
    names = {e["name"] for e in doc["traceEvents"]}
    missing = REQUIRED_SPANS - names
    assert not missing, f"span families missing from trace: {missing}"

    path = tr.save(out)
    st = res.stats
    print(tr.summary())
    print(f"\n{len(doc['traceEvents'])} events "
          f"({len(names)} span names) -> {path}")
    print(f"run: {st.n_splits} splits, wall={st.wall_s * 1e3:.1f} ms, "
          f"energy={st.energy_j:.2f} J ({st.energy_source}), "
          f"{st.rows_per_joule:.0f} rows/J")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:2]))
