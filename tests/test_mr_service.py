"""MapReduce query service: resident catalog, admission batching, guards.

The service's contract has three layers, tested bottom-up:
- ``shuffle_once`` / ``ResidentCatalog``: one shuffle, many bit-identical
  reduces (the ``run_jobs`` decomposition both the batch path and the
  service share);
- ``MRQueryService``: submit queue -> micro-batches -> coalesced fused
  reduces, with per-request ``RequestStats`` and the closed-state guard;
- determinism: ANY partition of a request set into micro-batches returns
  the same per-request outputs as single-request execution (fixed cases
  here; the hypothesis property lives in ``test_mapreduce_props.py``, and
  the 8-device mesh variant in ``md_check.py mapreduce-service``).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data import sky
from repro.mapreduce import (RequestStats, ZonePartitioner,
                             group_batch_compatible, latency_summary,
                             neighbor_search_job, neighbor_statistics_job,
                             run_job, run_jobs, shuffle_once,
                             shuffle_signature, token_histogram_job)
from repro.serving import MRQueryService

RADIUS = 0.1


def _setup(n=600, seed=3, codec="int16"):
    xyz = sky.make_catalog(n, seed)
    part = ZonePartitioner(RADIUS)
    edges = np.linspace(0.03, RADIUS, 4)
    jobs = [neighbor_search_job(RADIUS, partitioner=part, codec=codec,
                                tile=64),
            neighbor_search_job(RADIUS / 2, partitioner=part, codec=codec,
                                tile=64),
            neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                    codec=codec, tile=64)]
    return xyz, part, jobs


# ---------------------------------------------------------------------------
# ResidentCatalog: the shuffle-then-reduce decomposition
# ---------------------------------------------------------------------------

def test_resident_catalog_matches_run_jobs():
    """shuffle_once + run == run_jobs bit-for-bit, and repeated runs reuse
    the resident tiers (zero map/shuffle wall on the request stats)."""
    xyz, part, jobs = _setup()
    mono = run_jobs(jobs, xyz)
    cat = shuffle_once(part, xyz, codec="int16", tile=64)
    res = cat.run(jobs)
    assert res[0].output == mono[0].output
    assert res[1].output == mono[1].output
    np.testing.assert_array_equal(res[2].output, mono[2].output)
    again = cat.run(jobs[0])
    assert again[0].output == mono[0].output
    assert again[0].stats.map_wall_s == 0.0
    assert again[0].stats.shuffle_wall_s == 0.0
    assert again[0].stats.reduce_wall_s > 0.0
    assert cat.load_stats.shuffle_wall_s > 0.0
    assert cat.nbytes > 0 and cat.n_rows == len(xyz)


def test_resident_catalog_rejects_incompatible_jobs():
    xyz, part, jobs = _setup()
    cat = shuffle_once(part, xyz, codec="int16", tile=64)
    other_part = neighbor_search_job(0.05, tile=64)          # own partitioner
    with pytest.raises(ValueError, match="partitioner"):
        cat.run(other_part)
    with pytest.raises(ValueError, match="codec"):
        cat.run(neighbor_search_job(RADIUS, partitioner=part,
                                    codec="identity", tile=64))
    with pytest.raises(ValueError, match="tile"):
        cat.run(neighbor_search_job(RADIUS, partitioner=part, codec="int16",
                                    tile=128))


def test_shuffle_signature_grouping():
    xyz, part, jobs = _setup()
    other = neighbor_search_job(0.05, codec="int16", tile=64)
    assert shuffle_signature(jobs[0]) == shuffle_signature(jobs[2])
    assert shuffle_signature(jobs[0]) != shuffle_signature(other)
    groups = group_batch_compatible([jobs[0], other, jobs[2], jobs[1]])
    assert [len(g) for g in groups] == [3, 1]
    assert groups[0] == [jobs[0], jobs[2], jobs[1]]          # order kept


# ---------------------------------------------------------------------------
# MRQueryService: queueing, coalescing, accounting
# ---------------------------------------------------------------------------

def test_service_serves_and_coalesces_duplicates():
    """Duplicate queries in one admission window run ONCE (including
    separately-constructed equal jobs); every request still gets its own
    output and RequestStats."""
    xyz, part, jobs = _setup()
    dup = neighbor_search_job(RADIUS, partitioner=part, codec="int16",
                              tile=64)                       # == jobs[0]
    svc = MRQueryService(max_batch=8)
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    reqs = [svc.submit(j, catalog="sky") for j in jobs + [dup, jobs[0]]]
    assert svc.pending == 5
    assert svc.run_pending() == 5
    assert svc.batches == [dict(batch=0, size=5, n_unique=3,
                                wall_s=svc.batches[0]["wall_s"])]
    singles = [run_job(j, xyz).output for j in jobs]
    for r, want in zip(reqs, singles + [singles[0], singles[0]]):
        np.testing.assert_array_equal(r.output, want)
        assert r.done and r.stats.batch_size == 5 and r.stats.n_unique == 3
        assert r.stats.latency_s >= r.stats.queue_wait_s >= 0.0
    s = svc.latency_summary()
    assert s["n"] == 5 and s["mean_batch"] == 5.0 and s["qps"] > 0


def test_service_any_fixed_microbatch_partition_matches_single():
    """Fixed-case version of the hypothesis property (runs without the
    optional dependency): several partitions of one request stream into
    micro-batches all reproduce single-request outputs exactly."""
    xyz, part, jobs = _setup()
    stream = [jobs[i % 3] for i in range(7)]
    singles = [run_job(j, xyz).output for j in stream]
    for sizes in ([1] * 7, [7], [2, 3, 2], [3, 4], [5, 1, 1]):
        svc = MRQueryService(max_batch=16)
        svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
        reqs = [svc.submit(j, catalog="sky") for j in stream]
        svc.run_pending(batch_sizes=sizes)
        assert [b["size"] for b in svc.batches] == list(sizes)
        for r, want in zip(reqs, singles):
            np.testing.assert_array_equal(r.output, want)
        svc.close()


def test_service_multi_catalog_batch():
    """One admission window spanning catalogs: each group reduces against
    its own resident shuffle (sky zones + token hash partitions)."""
    xyz, part, jobs = _setup()
    toks = np.random.default_rng(0).integers(0, 40, 800)
    items = toks.astype(np.float32).reshape(-1, 1)
    wjob = token_histogram_job(40, tile=64, codec="int16")
    svc = MRQueryService(max_batch=8)
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    svc.load_catalog("tokens", items, wjob.partitioner, codec=wjob.codec,
                     tile=64, pad_value=wjob.reducer.pad_value)
    r1 = svc.submit(jobs[0], catalog="sky")
    r2 = svc.submit(wjob, catalog="tokens")
    r3 = svc.submit(token_histogram_job(40, tile=64, codec="int16"),
                    catalog="tokens")                        # equal, coalesces
    svc.run_pending()
    assert svc.batches[0]["size"] == 3 and svc.batches[0]["n_unique"] == 2
    assert r1.output == run_job(jobs[0], xyz).output
    np.testing.assert_array_equal(r2.output,
                                  np.bincount(toks, minlength=40))
    np.testing.assert_array_equal(r3.output, r2.output)


def test_service_threaded_context_manager():
    xyz, part, jobs = _setup()
    svc = MRQueryService(max_batch=4, max_wait_s=0.001)
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    want = run_job(jobs[0], xyz).output
    with svc:
        reqs = [svc.submit(jobs[0], catalog="sky") for _ in range(9)]
        outs = [r.result(timeout=120) for r in reqs]
    assert outs == [want] * 9
    assert sum(b["size"] for b in svc.batches) == 9
    assert all(b["n_unique"] == 1 for b in svc.batches)


def test_service_closed_guard():
    """Satellite: like ServeEngine after run() drains, a closed service
    rejects submissions instead of silently enqueueing them forever."""
    xyz, part, jobs = _setup(n=80)
    svc = MRQueryService()
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    req = svc.submit(jobs[0], catalog="sky")
    svc.close()                        # drains the pending request first
    assert req.done and svc.pending == 0
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(jobs[0], catalog="sky")
    with pytest.raises(RuntimeError, match="closed"):
        svc.start()
    with pytest.raises(RuntimeError, match="closed"):
        svc.load_catalog("more", xyz, part)
    svc.close()                        # idempotent


def test_service_submit_validates_at_the_door():
    xyz, part, jobs = _setup(n=80)
    svc = MRQueryService()
    with pytest.raises(KeyError, match="no catalog"):
        svc.submit(jobs[0], catalog="sky")
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    with pytest.raises(ValueError, match="codec"):
        svc.submit(neighbor_search_job(RADIUS, partitioner=part, tile=64),
                   catalog="sky")
    assert svc.pending == 0            # nothing half-enqueued


def test_service_straggler_monitor_hook():
    """Per-batch walls reach the monitor with the executor's record()
    contract: one call per micro-batch, indexed by batch."""
    recorded = []

    class Monitor:
        def record(self, k, wall_s):
            recorded.append((k, wall_s))

    xyz, part, jobs = _setup(n=200)
    svc = MRQueryService(max_batch=2, straggler_monitor=Monitor())
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    for _ in range(5):
        svc.submit(jobs[0], catalog="sky")
    svc.run_pending()
    assert [k for k, _ in recorded] == [0, 1, 2]
    assert all(w > 0 for _, w in recorded)
    assert [w for _, w in recorded] == [b["wall_s"] for b in svc.batches]


def test_latency_summary_math():
    reqs = [RequestStats(rid=i, t_submit_s=0.1 * i, queue_wait_s=0.01,
                         latency_s=0.2 + 0.01 * i, batch_size=2)
            for i in range(10)]
    s = latency_summary(reqs)
    assert s["n"] == 10 and s["mean_batch"] == 2.0
    # span = last done (0.9 + 0.29) - first submit (0.0)
    assert s["qps"] == pytest.approx(10 / (0.9 + 0.29))
    assert s["p50_ms"] == pytest.approx(245.0)
    assert s["wait_p50_ms"] == pytest.approx(10.0)
    assert s["p99_ms"] <= 290.0
    empty = latency_summary([])
    assert empty["n"] == 0 and empty["qps"] == 0.0


@pytest.mark.slow
def test_service_sharded_multidevice():
    """The 8-device mesh service parity check (subprocess: resident sharded
    catalog == per-query mesh run == host oracle, with coalescing)."""
    script = os.path.join(os.path.dirname(__file__), "md_check.py")
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, script, "mapreduce-service"],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, (
        f"mapreduce-service failed:\n{r.stdout}\n{r.stderr}")
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# failure isolation + lane serving (PR 7)
# ---------------------------------------------------------------------------

@pytest.mark.timeout_s(300)
def test_service_poison_request_does_not_fail_batchmates():
    """Regression: one request whose job fails mid-run (passes door
    validation, raises at reduce trace) must fail ALONE — its coalesced
    batch-mates are recovered with per-job fallback runs and still get
    bit-exact outputs."""
    import dataclasses

    from repro.mapreduce import MapReduceJob, Reducer

    @dataclasses.dataclass(frozen=True)
    class PoisonReducer(Reducer):
        pad_value: float = 0.0

        def per_partition(self, owned_p, bucket_p):
            raise ValueError("poison: invalid query parameters")

    xyz, part, jobs = _setup()
    singles = [run_job(j, xyz).output for j in jobs]
    svc = MRQueryService(max_batch=8)
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    good = [svc.submit(j, catalog="sky") for j in jobs]
    poison = MapReduceJob(name="poison", partitioner=part,
                          reducer=PoisonReducer(), codec="int16", tile=64)
    bad = svc.submit(poison, catalog="sky")
    assert svc.run_pending() == 4
    for r, want in zip(good, singles):
        assert r.error is None
        np.testing.assert_array_equal(r.output, want)
    assert bad.done and isinstance(bad.error, ValueError)
    with pytest.raises(ValueError, match="poison"):
        bad.result(timeout=5)
    # exactly one batch recorded, containing all 4 requests
    assert len(svc.batches) == 1 and svc.batches[0]["size"] == 4


@pytest.mark.timeout_s(300)
def test_service_lanes_concurrent_batches_and_lane_death():
    """Lane-backed serving: micro-batches run concurrently on a LanePool;
    an injected lane death shrinks the pool and requeues the batch instead
    of killing the service — every request still gets the exact answer."""
    from repro.ft import LaneChaos

    xyz, part, jobs = _setup()
    singles = [run_job(j, xyz).output for j in jobs]
    chaos = LaneChaos(kills=[(0, 0)])
    svc = MRQueryService(max_batch=2, max_wait_s=0.001, n_lanes=3,
                         lane_chaos=chaos)
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    with svc:
        reqs = [svc.submit(jobs[i % 3], catalog="sky") for i in range(8)]
        outs = [r.result(timeout=120) for r in reqs]
    for got, i in zip(outs, range(8)):
        want = singles[i % 3]
        if isinstance(want, np.ndarray):
            np.testing.assert_array_equal(got, want)
        else:
            assert got == want
    assert len(chaos.deaths) == 1          # the kill actually fired
    assert sum(b["size"] for b in svc.batches) == 8
    # close() joined the pool: no leaked lane threads
    assert svc._pool is None
