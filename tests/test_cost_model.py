"""Cost model: calibration/cache plumbing, predictors, auto-knob identity.

Two contracts matter most and get property checks here:

1. **Bit identity** — every ``"auto"`` knob (codec/tile/split_rows/chunk
   shape) may change SHAPES and CHOICES, never arithmetic: auto results
   equal manual results exactly, on both engines.
2. **Planner parity** — the vectorized ``plan_tiers`` is
   behavior-identical to the original exhaustive ``itertools.combinations``
   search (copied verbatim below as the oracle), including tie-breaks, and
   stays fast at pathological unique-capacity counts.
"""
import dataclasses
import itertools
import json
import time

import numpy as np
import pytest

import repro.core.cost_model as cm
from repro.core.cost_model import (BackendProfile, CostModel, StageCost,
                                   backend_fingerprint, calibration_enabled,
                                   get_cost_model, reset_cost_model)
from repro.data import sky
from repro.mapreduce import (get_codec, neighbor_search_job, plan_tiers,
                             run_job, token_histogram_job)
from repro.mapreduce.job import _round_up


@pytest.fixture
def isolated_model(monkeypatch, tmp_path):
    """Point the disk cache at a tmp dir and drop process-cached models, so
    tests never see (or write) the user's real calibration cache."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_CALIBRATE", raising=False)
    reset_cost_model()
    yield tmp_path
    reset_cost_model()


# ---------------------------------------------------------------------------
# profiles, calibration guards, disk cache
# ---------------------------------------------------------------------------

def test_default_profile_is_analytic_and_uncalibrated(isolated_model):
    m = get_cost_model()
    assert not m.profile.calibrated
    assert m.profile.fingerprint == backend_fingerprint()
    assert m.profile.flops_per_s > 0 and m.profile.bytes_per_s > 0
    # process cache: same object back
    assert get_cost_model() is m


def test_no_calibrate_env_disables_replay(isolated_model, monkeypatch):
    monkeypatch.setenv("REPRO_NO_CALIBRATE", "1")
    assert not calibration_enabled()
    m = CostModel.load(calibrate=True)
    assert not m.profile.calibrated          # replay skipped, defaults used


SYNTH_PROBES = (
    # (tm, tn, b0, wall_s, flops, hbm_bytes) at F=1e10, B=5e9, c=2e-5
    (8, 8, 8, 2.01e-5, 1.0e3, 2.0e2),
    (32, 32, 256, 2.0e-5 + 1e-2 + 2e-3, 1.0e8, 1.0e7),
    (64, 64, 256, 2.0e-5 + 2e-2 + 4e-3, 2.0e8, 2.0e7),
    (64, 64, 512, 2.0e-5 + 4e-2 + 6e-3, 4.0e8, 3.0e7),
    (128, 128, 512, 2.0e-5 + 8e-2 + 1e-2, 8.0e8, 5.0e7),
)


def test_fit_profile_recovers_synthetic_rates():
    p = cm._fit_profile("fp", SYNTH_PROBES)
    assert p.calibrated and p.probes == SYNTH_PROBES
    assert p.flops_per_s == pytest.approx(1e10, rel=0.25)
    assert p.bytes_per_s == pytest.approx(5e9, rel=0.25)
    # anchor probe pins dispatch near c
    assert p.dispatch_s == pytest.approx(2.01e-5, rel=0.05)
    # prediction round-trip on a probe the fit saw: within 2x
    w = CostModel(p).predict_wall(StageCost(flops=4.0e8, hbm_bytes=3.0e7))
    assert 0.5 < w / SYNTH_PROBES[3][3] < 2.0


def test_calibration_cache_roundtrip_and_invalidation(isolated_model,
                                                      monkeypatch):
    monkeypatch.setattr(cm, "calibration_enabled", lambda: True)
    monkeypatch.setattr(cm, "_run_replay", lambda: SYNTH_PROBES)
    m = CostModel.load(calibrate=True)
    assert m.profile.calibrated
    path = cm.cache_path(backend_fingerprint())
    assert json.load(open(path))["fingerprint"] == backend_fingerprint()

    # a later load (no calibrate) reads the cache — replay must NOT run
    monkeypatch.setattr(cm, "_run_replay",
                        lambda: pytest.fail("replay ran on cached load"))
    m2 = CostModel.load()
    assert m2.profile.calibrated
    assert m2.profile.probes == SYNTH_PROBES

    # fingerprint mismatch (backend changed) invalidates the cache file
    d = json.load(open(path))
    d["fingerprint"] = "other|backend"
    json.dump(d, open(path, "w"))
    assert cm._load_cached(backend_fingerprint()) is None
    assert not CostModel.load().profile.calibrated

    # corrupt JSON is treated as a miss, not an error
    open(path, "w").write("{not json")
    assert cm._load_cached(backend_fingerprint()) is None


# ---------------------------------------------------------------------------
# predictors and choosers
# ---------------------------------------------------------------------------

def test_argmin_first_wins_ties(isolated_model):
    m = get_cost_model()
    c = StageCost(flops=1e6)
    key, wall = m.argmin([("a", c), ("b", c), ("c", StageCost(flops=1e9))])
    assert key == "a" and wall > 0
    with pytest.raises(ValueError):
        m.argmin([])


def test_choose_codec_returns_exact(isolated_model):
    m = get_cost_model()
    name = m.choose_codec(d=3)
    assert get_codec(name).exact
    # restricting candidates to a lossy codec must fail, not fall back
    with pytest.raises(ValueError):
        m.choose_codec(candidates=["int8"])


def test_predict_stage_wall_accepts_callable(isolated_model):
    import jax.numpy as jnp
    m = get_cost_model()
    x = jnp.ones((64, 64), jnp.float32)
    w = m.predict_stage_wall(lambda a: a @ a, x)
    assert w > 0.0


def test_plan_shuffle_covers_partitions(isolated_model):
    m = get_cost_model()
    rng = np.random.default_rng(0)
    n_bucket = np.concatenate([[5000], rng.integers(1, 80, 31)])
    n_owned = (n_bucket * 0.7).astype(np.int64)
    tile, plan, wall = m.plan_shuffle(n_owned, n_bucket)
    assert tile in cm.TILE_CANDIDATES and wall > 0
    ids = np.sort(np.concatenate([t[0] for t in plan]))
    np.testing.assert_array_equal(ids, np.arange(32))


def test_rows_basis_charges_per_tier_overhead(isolated_model):
    # linear reducers: splitting the same rows over 3 tiers must predict
    # slower than 1 tier (tiering buys no arithmetic back, costs dispatches)
    f = get_cost_model().tier_cost_fn(basis="rows")
    one = float(np.sum(f([16], [256], [256])))
    three = float(np.sum(f([6, 5, 5], [256, 256, 256], [64, 128, 256])))
    assert three > one


# ---------------------------------------------------------------------------
# plan_tiers: oracle parity + speed bound
# ---------------------------------------------------------------------------

def _plan_tiers_oracle(n_owned, n_bucket, tile, max_tiers=3,
                       pad_partitions_to=1):
    """The original O(U choose k) search, verbatim (PR 6-8 behavior)."""
    n_owned = np.asarray(n_owned, np.int64)
    n_bucket = np.asarray(n_bucket, np.int64)
    caps = np.array([_round_up(int(c), tile) for c in n_bucket], np.int64)
    uniq = np.unique(caps)

    def cost_and_tiers(thresholds):
        cost, tiers, lo = 0.0, [], -1
        for th in thresholds:
            sel = np.flatnonzero((caps > lo) & (caps <= th))
            lo = th
            if not len(sel):
                continue
            C1 = _round_up(int(n_owned[sel].max()), tile)
            cost += float(_round_up(len(sel), pad_partitions_to)) * C1 * th
            tiers.append((sel, C1, int(th)))
        return cost, tiers

    best = cost_and_tiers([int(uniq[-1])])
    for k in range(2, min(max_tiers, len(uniq)) + 1):
        for cut in itertools.combinations(range(len(uniq) - 1), k - 1):
            cand = cost_and_tiers([int(uniq[i]) for i in cut]
                                  + [int(uniq[-1])])
            if cand[0] < best[0]:
                best = cand
    return best[1]


@pytest.mark.parametrize("seed", range(40))
def test_plan_tiers_matches_exhaustive_oracle(seed):
    rng = np.random.default_rng(seed)
    P = int(rng.integers(1, 40))
    tile = int(rng.choice([1, 8, 64, 256]))
    pad = int(rng.choice([1, 2, 4]))
    kmax = int(rng.choice([1, 2, 3, 4]))
    n_bucket = rng.integers(0, 2000, P)
    n_owned = rng.integers(0, 2000, P)
    got = plan_tiers(n_owned, n_bucket, tile, max_tiers=kmax,
                     pad_partitions_to=pad)
    want = _plan_tiers_oracle(n_owned, n_bucket, tile, max_tiers=kmax,
                              pad_partitions_to=pad)
    assert len(got) == len(want)
    for (gi, gc1, gc2), (wi, wc1, wc2) in zip(got, want):
        np.testing.assert_array_equal(gi, wi)
        assert (gc1, gc2) == (wc1, wc2)


def test_plan_tiers_500_unique_capacities_under_1s():
    # tile=1 keeps every capacity distinct: U=500 was minutes with the old
    # O(U^2) combinations search; the vectorized table + early exit must
    # plan it in well under a second.
    rng = np.random.default_rng(7)
    n_bucket = rng.permutation(np.arange(1, 501))
    n_owned = rng.integers(1, 500, 500)
    t0 = time.perf_counter()
    plan = plan_tiers(n_owned, n_bucket, 1, max_tiers=3)
    dt = time.perf_counter() - t0
    assert dt < 1.0, f"500-unique plan took {dt:.2f}s"
    ids = np.sort(np.concatenate([t[0] for t in plan]))
    np.testing.assert_array_equal(ids, np.arange(500))


# ---------------------------------------------------------------------------
# auto knobs: bit identity + recorded predictions
# ---------------------------------------------------------------------------

def test_auto_knobs_bit_identical_property(isolated_model):
    for seed in range(4):
        rng = np.random.default_rng(seed)
        xyz = sky.make_catalog(int(rng.integers(200, 1200)), seed)
        for engine in ("device", "host"):
            hand = neighbor_search_job(0.05, tile=256)
            auto = dataclasses.replace(hand, codec="auto", tile="auto")
            r_hand = run_job(hand, xyz, engine=engine)
            r_auto = run_job(auto, xyz, engine=engine)
            assert r_auto.output == r_hand.output
            assert r_auto.stats.codec in ("identity", "int16")
            assert get_codec(r_auto.stats.codec).exact


def test_auto_knobs_bit_identical_wordcount(isolated_model):
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 3000, 20000)
    hand = token_histogram_job(3000, n_partitions=8, tile=256)
    auto = dataclasses.replace(hand, codec="auto", tile="auto")
    for engine in ("device", "host"):
        np.testing.assert_array_equal(
            run_job(auto, toks, engine=engine).output,
            run_job(hand, toks, engine=engine).output)


def test_predicted_walls_recorded_and_error_observable(isolated_model):
    xyz = sky.make_catalog(3000, 0)
    r = run_job(neighbor_search_job(0.05), xyz, engine="device")
    st = r.stats
    assert st.predicted_shuffle_wall_s > 0
    assert st.predicted_reduce_wall_s > 0
    assert st.prediction_error > 0
    assert "prediction_error" in st.to_dict()
    # host engine never records device predictions -> error reads 0.0
    st2 = run_job(neighbor_search_job(0.05), xyz, engine="host").stats
    assert st2.prediction_error == 0.0


@pytest.mark.skipif(not calibration_enabled(),
                    reason="calibration needs >=2 CPUs and no opt-out")
def test_calibrated_prediction_within_2x(isolated_model):
    # acceptance: on a calibrated backend the predicted wall of the probe
    # kernel itself must land within 2x of its measured wall
    m = get_cost_model(calibrate=True)
    assert m.profile.calibrated
    for (tm, tn, b0, wall, flops, byts) in m.profile.probes[1:]:
        pred = m.predict_wall(StageCost(flops=flops, hbm_bytes=byts))
        assert 0.5 < pred / wall < 2.0, (tm, tn, b0, pred, wall)


# ---------------------------------------------------------------------------
# blocked chunk shape + sizing helpers
# ---------------------------------------------------------------------------

def test_blocked_chunk_override_is_exact(isolated_model):
    from repro.kernels.zones_pairs import blocked
    xyz = sky.make_catalog(4000, 1)
    job = neighbor_search_job(0.03)
    want = run_job(job, xyz, engine="device").output
    blocked.set_chunk_shape(32, 32, 128)
    try:
        assert blocked.chunk_shape() == (32, 32, 128)
        assert run_job(job, xyz, engine="device").output == want
    finally:
        blocked.set_chunk_shape()
    assert blocked.chunk_shape() == (blocked.TM, blocked.TN, blocked.B0)


def test_auto_chunk_uncalibrated_keeps_default(isolated_model, monkeypatch):
    from repro.kernels.zones_pairs import blocked
    monkeypatch.setenv("REPRO_AUTO_CHUNK", "1")
    assert blocked.chunk_shape() == (blocked.TM, blocked.TN, blocked.B0)


def test_choose_blocked_chunk_prefers_measured_faster(isolated_model):
    # synthetic probes where (128,128,512) amortizes dispatch best
    probes = ((8, 8, 8, 1.0e-5, 1e3, 2e2),
              (64, 64, 512, 3.0e-3, 4e8, 3e7),
              (128, 128, 512, 4.0e-3, 16e8, 6e7))
    m = CostModel(BackendProfile("fp", 1e10, 5e9, 1e-5, calibrated=True,
                                 probes=probes))
    assert m.choose_blocked_chunk() == (128, 128, 512)


def test_choose_split_rows_bounds(isolated_model):
    m = get_cost_model()
    n = m.choose_split_rows(10_000_000, d=3)
    assert 1 <= n <= 10_000_000
    # byte cap binds for huge rows
    assert m.choose_split_rows(10**9, bytes_per_row=1e6,
                               max_split_bytes=128e6) <= 128
    assert m.choose_split_rows(5) <= 5


def test_choose_spill_ranges_bounds(isolated_model):
    m = get_cost_model()
    assert m.choose_spill_ranges(0.0, 1e9, P=64) == 1
    assert m.choose_spill_ranges(1e12, 1e6, P=64) == 64          # capped at P
    assert m.choose_spill_ranges(1e9, 1e9, P=256, max_ranges=8) <= 8
    # needs ceil(est / (budget/2)) ranges
    assert m.choose_spill_ranges(10e6, 4e6, P=256) == 5


def test_spill_auto_ranges_wiring(isolated_model, tmp_path):
    from repro.data import ArraySplits
    from repro.mapreduce import SpillConfig, run_job_streaming
    xyz = sky.make_catalog(6000, 4)
    job = neighbor_search_job(0.03, tile=128)
    want = run_job(job, xyz).output
    res = run_job_streaming(
        job, ArraySplits(xyz, n_splits=4),
        spill=SpillConfig(budget_bytes=20_000, dir=str(tmp_path / "sp"),
                          n_ranges="auto"))
    assert res.output == want
    assert res.stats.spill_ranges >= 1
