"""Job API properties: codec contracts, engine parity, wrapper/oracle
agreement, multi-job batching, and StageStats -> Amdahl accounting."""
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.data import sky
from repro.mapreduce import (HashPartitioner, MapReduceJob, ZonePartitioner,
                             available_codecs, get_codec,
                             neighbor_pairs_dense, neighbor_search_count,
                             neighbor_search_job, neighbor_statistics,
                             neighbor_statistics_job, run_job, run_jobs,
                             token_histogram)
from repro.mapreduce.codecs import Int16Codec


# ---------------------------------------------------------------------------
# ShuffleCodec contracts (property-style sweep over the registry)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(available_codecs()))
@pytest.mark.parametrize("n,d,seed", [(1, 1, 0), (7, 3, 1), (256, 3, 2),
                                      (1000, 3, 3), (513, 2, 4)])
def test_codec_roundtrip_within_tolerance(name, n, d, seed):
    codec = get_codec(name)
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, (n, d)).astype(np.float32)
    back = codec.roundtrip(x)
    assert back.shape == x.shape
    err = np.max(np.abs(back - x))
    assert err <= codec.error_bound(x) + 1e-7, (name, err)


@pytest.mark.parametrize("name", sorted(available_codecs()))
def test_codec_wire_bytes_accounting(name):
    """encode() payload bytes == the static nbytes() formula the engine uses."""
    codec = get_codec(name)
    for n in (1, 255, 256, 257, 4096):
        x = np.linspace(-1, 1, n, dtype=np.float32)
        enc = codec.encode(x)
        assert enc.wire_bytes == codec.nbytes(n), (name, n)
        assert sum(a.nbytes for a in enc.arrays) == enc.wire_bytes, (name, n)


def test_codec_relative_sizes():
    """identity : int16 : int8 wire bytes ~= 4 : 2 : 1 (+ scale overhead)."""
    n = 3 * 4096
    idn = get_codec("identity").nbytes(n)
    i16 = get_codec("int16").nbytes(n)
    i8 = get_codec("int8").nbytes(n)
    assert idn == 4 * n and idn == 2 * i16
    assert i8 < i16 < idn
    assert i8 == n + 4 * (n // 256)        # int8 codes + one fp32 scale/block


def test_codec_registry_rejects_unknown():
    with pytest.raises(KeyError):
        get_codec("lzo")


def test_int8_codec_custom_block_roundtrips():
    from repro.mapreduce.codecs import Int8BlockCodec
    rng = np.random.default_rng(7)
    x = rng.normal(size=(300,)).astype(np.float32)   # not a block multiple
    for block in (64, 128, 512):
        codec = Int8BlockCodec(block=block)
        back = codec.roundtrip(x)
        assert np.max(np.abs(back - x)) <= codec.error_bound(x) + 1e-7
        assert codec.encode(x).wire_bytes == codec.nbytes(x.size)


# ---------------------------------------------------------------------------
# Engine: jobs vs oracles, batching, codecs interchangeable
# ---------------------------------------------------------------------------

def test_search_job_matches_oracle_all_codecs():
    """Codecs are interchangeable; count error tracks each codec's error
    bound (identity exact; int16 ~1/32767/coord; int8 ~1/127/coord, so it
    needs a radius well above its quantization step)."""
    xyz = sky.make_catalog(700, 11)
    for codec, radius, rel_tol in [("identity", 0.06, 0.0),
                                   ("int16", 0.06, 0.02),
                                   ("int8", 0.2, 0.05)]:
        want = sky.brute_force_pairs(xyz, radius)
        got = run_job(neighbor_search_job(radius, codec=codec, tile=64),
                      xyz).output
        assert abs(got - want) <= max(3 * bool(rel_tol), rel_tol * want), (
            codec, got, want)


def test_batched_jobs_share_one_shuffle():
    xyz = sky.make_catalog(600, 2)
    edges = np.linspace(0.02, 0.1, 5)
    part = ZonePartitioner(float(edges[-1]))
    jobs = [neighbor_search_job(float(edges[-1]), partitioner=part, tile=64),
            neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                    tile=64)]
    rs = run_jobs(jobs, xyz)
    assert rs[0].output == sky.brute_force_pairs(xyz, float(edges[-1]))
    np.testing.assert_array_equal(
        rs[1].output, sky.brute_force_hist(xyz, np.concatenate([[0], edges])))
    assert rs[0].stats is rs[1].stats          # one shuffle, shared stats
    assert rs[0].stats.job == "neighbor_search+neighbor_statistics"


def test_batched_jobs_reject_mismatched_stages():
    with pytest.raises(ValueError):
        run_jobs([neighbor_search_job(0.1, tile=64),
                  neighbor_search_job(0.1, tile=128)],
                 sky.make_catalog(50, 0))


def test_wordcount_matches_bincount_and_compresses():
    toks = np.random.default_rng(3).integers(0, 700, 6000)
    want = np.bincount(toks, minlength=700)
    r_id = token_histogram(toks, 700, tile=64)
    r_16 = token_histogram(toks, 700, codec="int16", tile=64)
    np.testing.assert_array_equal(r_id.output, want)
    np.testing.assert_array_equal(r_16.output, want)   # lossless: vocab < 32767
    assert r_16.stats.shuffle_wire_bytes * 2 == r_id.stats.shuffle_wire_bytes


def test_custom_job_composition():
    """A from-scratch job (hash partitioner + custom reducer) runs on the
    same engine: partition-sum of squares == global sum of squares."""
    import jax.numpy as jnp
    from repro.mapreduce import Reducer

    class SumSquares(Reducer):
        def per_partition(self, owned_p, bucket_p):
            return jnp.sum(owned_p[:, 0] ** 2)

    vals = np.arange(1, 501, dtype=np.float32)
    job = MapReduceJob("sumsq", HashPartitioner(4), SumSquares(), tile=32)
    got = float(run_job(job, vals).output)
    assert np.isclose(got, float(np.sum(vals ** 2)), rtol=1e-6)


# ---------------------------------------------------------------------------
# Engine parity: device (wire-dtype shuffle + tiered masked reduce) == host
# ---------------------------------------------------------------------------

def test_engine_parity_search_stats_wordcount():
    """engine="device" must match engine="host" EXACTLY for all three jobs
    with the exact (identity) and int16 codecs."""
    xyz = sky.make_catalog(1500, 9)
    radius = 0.07
    edges = np.linspace(0.02, radius, 6)
    toks = np.random.default_rng(5).integers(0, 900, 5000)
    for codec in ("identity", "int16"):
        sjob = neighbor_search_job(radius, codec=codec, tile=64)
        hjob = neighbor_statistics_job(edges / sky.ARCSEC, codec=codec,
                                       tile=64)
        assert (run_job(sjob, xyz, engine="device").output
                == run_job(sjob, xyz, engine="host").output)
        np.testing.assert_array_equal(
            run_job(hjob, xyz, engine="device").output,
            run_job(hjob, xyz, engine="host").output)
        np.testing.assert_array_equal(
            token_histogram(toks, 900, codec=codec, tile=64,
                            engine="device").output,
            token_histogram(toks, 900, codec=codec, tile=64,
                            engine="host").output)


def test_engine_parity_batched_and_skewed():
    """Batched jobs over one shuffle, with a skewed catalog (one crowded
    zone) so the tier planner actually splits size classes."""
    from repro.mapreduce import plan_tiers
    rng = np.random.default_rng(11)
    xyz = sky.make_catalog(900, 1)
    xyz = np.concatenate([xyz, sky.make_catalog(600, 2) * 0 + xyz[:1]])
    xyz[900:, 2] = np.clip(xyz[900:, 2] + rng.normal(0, 1e-3, 600), -1, 1)
    n = np.linalg.norm(xyz, axis=1, keepdims=True)
    xyz = (xyz / n).astype(np.float32)
    radius = 0.08
    part = ZonePartitioner(radius)
    edges = np.linspace(0.02, radius, 4)
    jobs = [neighbor_search_job(radius, partitioner=part, tile=64),
            neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                    tile=64)]
    rd = run_jobs(jobs, xyz, engine="device")
    rh = run_jobs(jobs, xyz, engine="host")
    assert rd[0].output == rh[0].output
    np.testing.assert_array_equal(rd[1].output, rh[1].output)
    assert rd[0].stats.engine == "device" and rh[0].stats.engine == "host"
    # the skewed zone must land in its own capacity tier
    keys = part.assign(xyz)
    n_owned = np.bincount(keys, minlength=part.n_partitions(xyz))
    tiers = plan_tiers(n_owned, n_owned * 2, 64)
    assert len(tiers) >= 2


def test_engine_parity_jnp_index_path():
    """The pure-jnp argsort/scatter path (used on accelerator backends) must
    match the numpy index path used on CPU — same results AND the same
    shuffle metadata, with the resolved choice recorded in StageStats so an
    "auto" run is never ambiguous about which path built its tiers."""
    from repro.mapreduce import job as job_mod
    xyz = sky.make_catalog(700, 3)
    sjob = neighbor_search_job(0.09, codec="int16", tile=64)
    want = run_job(sjob, xyz, engine="device")
    assert want.stats.shuffle_index_impl == "host"    # CPU backend default
    old = job_mod.SHUFFLE_INDEX_IMPL
    job_mod.SHUFFLE_INDEX_IMPL = "jnp"
    try:
        got = run_job(sjob, xyz, engine="device")
    finally:
        job_mod.SHUFFLE_INDEX_IMPL = old
    assert got.output == want.output
    assert got.stats.shuffle_index_impl == "jnp"
    for f in ("shuffle_wire_bytes", "shuffle_raw_bytes", "n_partitions",
              "reduce_padded_ratio", "shard_padded_ratio", "reduce_bytes"):
        assert getattr(got.stats, f) == getattr(want.stats, f), f


def test_device_engine_stats_and_wire_accounting():
    xyz = sky.make_catalog(800, 6)
    res = run_job(neighbor_search_job(0.06, codec="int16", tile=64), xyz,
                  engine="device")
    st = res.stats
    assert st.engine == "device"
    assert st.compression_ratio == pytest.approx(2.0)   # int16 wire dtype
    assert st.reduce_padded_ratio >= 1.0
    assert st.reduce_bytes > 0 and st.reduce_flops > 0
    assert "reduce_padded_ratio" in st.to_dict()


def test_device_engine_accepts_any_mesh():
    """Device is the default engine everywhere now — ``engine="auto"`` picks
    it even when a mesh is present (the data-axis fallback to host is gone;
    multi-shard parity runs in md_check's ``mapreduce-device`` mode)."""
    from repro.core.compat import make_mesh
    xyz = sky.make_catalog(100, 0)
    job = neighbor_search_job(0.1, tile=64)
    want = run_job(job, xyz, engine="host").output
    for mesh in (make_mesh((1,), ("model",)), make_mesh((1, 1),
                                                        ("data", "model"))):
        res = run_job(job, xyz, mesh=mesh)              # engine="auto"
        assert res.stats.engine == "device"
        assert res.output == want
    with pytest.raises(ValueError):
        run_jobs([job], xyz, engine="nonsense")


def test_plan_tiers_pad_partitions_constraint():
    """``pad_partitions_to`` charges phantom rows in the cost search and the
    engine pads every tier to a multiple of it; a partition-count floor that
    would split wastefully under a wide mesh collapses to fewer tiers."""
    from repro.mapreduce import plan_tiers
    n_owned = np.array([10, 12, 9, 300, 11, 8, 290, 13])
    n_bucket = n_owned * 2
    plan1 = plan_tiers(n_owned, n_bucket, 64)
    for pad in (1, 4, 8):
        plan = plan_tiers(n_owned, n_bucket, 64, pad_partitions_to=pad)
        # every partition appears exactly once across tiers
        all_ids = np.sort(np.concatenate([ids for ids, _, _ in plan]))
        np.testing.assert_array_equal(all_ids, np.arange(len(n_owned)))
        # no empty tiers ever (the "zero-partition tier" cannot occur)
        assert all(len(ids) > 0 for ids, _, _ in plan)
        # padded cost never better than the unpadded plan's padded cost
        def padded_cells(p):
            return sum(-(-len(ids) // pad) * pad * C1 * C2
                       for ids, C1, C2 in p)
        assert padded_cells(plan) <= padded_cells(plan1)


def test_device_engine_phantom_partition_accounting():
    """Tier Pt padding (phantom partitions) shows up in the per-shard stats:
    n_shards and a shard_padded_ratio per shard, present even off-mesh."""
    xyz = sky.make_catalog(500, 2)
    res = run_job(neighbor_search_job(0.08, tile=64), xyz, engine="device")
    st = res.stats
    assert st.n_shards == 1
    assert len(st.shard_padded_ratio) == 1
    assert st.shard_padded_ratio[0] == pytest.approx(st.reduce_padded_ratio)
    host = run_job(neighbor_search_job(0.08, tile=64), xyz, engine="host")
    assert host.stats.n_shards == 1
    assert len(host.stats.shard_padded_ratio) == 1


@pytest.mark.slow
def test_ragged_shards_match_host_mesh_oracle():
    """Tier counts not divisible by the data axis, a tier landing entirely
    on one shard, and zero-entry partitions / the empty catalog — all must
    match the host mesh oracle exactly (8 host devices, subprocess)."""
    script = os.path.join(os.path.dirname(__file__), "md_check.py")
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, script, "mapreduce-ragged"],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, (
        f"mapreduce-ragged failed:\n{r.stdout}\n{r.stderr}")
    assert "OK" in r.stdout


def test_device_engine_empty_catalog():
    """n=0 items: every stage must run clean and produce empty results."""
    xyz = np.zeros((0, 3), np.float32)
    job = neighbor_search_job(0.05, tile=64)
    assert run_job(job, xyz, engine="device").output == 0
    assert run_job(job, xyz, engine="host").output == 0
    hjob = neighbor_statistics_job([10.0, 20.0], tile=64)
    np.testing.assert_array_equal(
        run_job(hjob, xyz, engine="device").output, [0, 0])


# ---------------------------------------------------------------------------
# Split-streaming executor (the monolithic path is its one-split case)
# ---------------------------------------------------------------------------

def test_streaming_executor_stats_and_records():
    """Per-split records, fetch/overlap decomposition, and the aggregate
    stats contract of a streaming run (accumulate mode: pair job)."""
    from repro.data import ArraySplits
    from repro.mapreduce import run_job_streaming
    xyz = sky.make_catalog(1200, 8)
    job = neighbor_search_job(0.07, codec="int16", tile=64)
    mono = run_job(job, xyz)
    res = run_job_streaming(job, ArraySplits(xyz, 4), prefetch=2)
    assert res.output == mono.output
    st = res.stats
    assert st.n_splits == 4 and len(st.splits) == 4
    assert st.combiner == ""                    # pair kernels can't combine
    assert [r["split"] for r in st.splits] == [0, 1, 2, 3]
    assert sum(r["n_items"] for r in st.splits) == 1200
    assert st.n_items == 1200
    # streaming moves the same wire bytes as the monolithic shuffle
    assert st.shuffle_wire_bytes == mono.stats.shuffle_wire_bytes
    assert st.fetch_wall_s >= 0 and st.overlap_hidden_s >= 0
    assert 0.0 <= st.overlap_fraction <= 1.0
    d = st.to_dict()
    assert d["n_splits"] == 4 and "overlap_fraction" in d


def test_streaming_prefetch_off_matches_on():
    from repro.data import ArraySplits
    from repro.mapreduce import run_job_streaming
    xyz = sky.make_catalog(600, 3)
    job = neighbor_search_job(0.09, tile=64)
    a = run_job_streaming(job, ArraySplits(xyz, 3), prefetch=0)
    b = run_job_streaming(job, ArraySplits(xyz, 3), prefetch=2)
    assert a.output == b.output == run_job(job, xyz).output


def test_streaming_host_engine_matches_device():
    from repro.data import ArraySplits
    from repro.mapreduce import run_job_streaming, token_histogram_job
    xyz = sky.make_catalog(500, 6)
    job = neighbor_search_job(0.1, tile=64)
    dev = run_job_streaming(job, ArraySplits(xyz, 3), engine="device")
    host = run_job_streaming(job, ArraySplits(xyz, 3), engine="host")
    assert dev.output == host.output
    assert host.stats.engine == "host"
    toks = np.random.default_rng(9).integers(0, 50, 2000)
    items = toks.astype(np.float32).reshape(-1, 1)
    wjob = token_histogram_job(50, tile=64)
    for combiner in (None, "auto"):
        hd = run_job_streaming(wjob, ArraySplits(items, 5), engine="device",
                               combiner=combiner)
        hh = run_job_streaming(wjob, ArraySplits(items, 5), engine="host",
                               combiner=combiner)
        np.testing.assert_array_equal(hd.output, hh.output)
        np.testing.assert_array_equal(hd.output,
                                      np.bincount(toks, minlength=50))


def test_streaming_combiner_shrinks_wordcount_wire_bytes():
    """Map-side combine pre-aggregates each split to (token, count) rows, so
    for vocab << split size the wire carries ~vocab weighted entries instead
    of every occurrence — the paper's shrink-bytes-before-the-boundary move
    (>=2x is the fig4 bench gate; here the duplication factor is ~8x)."""
    from repro.data import ArraySplits
    from repro.mapreduce import run_job_streaming, token_histogram_job
    rng = np.random.default_rng(0)
    vocab, n = 64, 4096
    toks = rng.integers(0, vocab, n)
    items = toks.astype(np.float32).reshape(-1, 1)
    job = token_histogram_job(vocab, n_partitions=8, tile=64)
    on = run_job_streaming(job, ArraySplits(items, 4))
    off = run_job_streaming(job, ArraySplits(items, 4), combiner=None)
    np.testing.assert_array_equal(on.output, off.output)
    np.testing.assert_array_equal(on.output,
                                  np.bincount(toks, minlength=vocab))
    assert on.stats.combiner == "token_count" and off.stats.combiner == ""
    assert off.stats.shuffle_wire_bytes >= 2 * on.stats.shuffle_wire_bytes, (
        on.stats.shuffle_wire_bytes, off.stats.shuffle_wire_bytes)
    # n_items/map_bytes mean the RAW catalog even though the combiner
    # rewrote each split to (token, count) rows before the map
    assert on.stats.n_items == n == off.stats.n_items
    assert on.stats.map_bytes == items.nbytes
    assert sum(r["n_items"] for r in on.stats.splits) == n


def test_streaming_out_of_core_memmap_source(tmp_path):
    """A memmap-backed catalog 6x the split size streams split-by-split
    (nothing ever materializes the whole file) and matches the in-memory
    monolithic run bit-for-bit."""
    from repro.data import MemmapCatalogSplits
    from repro.mapreduce import run_job_streaming
    xyz = sky.make_catalog(1800, 12)
    path = str(tmp_path / "catalog.f32")
    MemmapCatalogSplits.write(path, xyz)
    src = MemmapCatalogSplits(path, d=3, rows_per_split=300)
    assert src.n_splits() == 6
    job = neighbor_search_job(0.06, codec="int16", tile=64)
    res = run_job_streaming(job, src)
    assert res.output == run_job(job, xyz).output
    assert res.stats.n_splits == 6
    assert max(r["n_items"] for r in res.stats.splits) == 300


def test_streaming_feeds_straggler_monitor():
    from repro.data import ArraySplits
    from repro.ft import StragglerMonitor
    from repro.mapreduce import run_job_streaming
    xyz = sky.make_catalog(400, 1)
    mon = StragglerMonitor(list(range(4)))
    run_job_streaming(neighbor_search_job(0.1, tile=64),
                      ArraySplits(xyz, 4), straggler_monitor=mon)
    assert sorted(mon.ema) == [0, 1, 2, 3]
    assert all(t >= 0 for t in mon.ema.values())


def test_streaming_rejects_bad_combiner():
    from repro.data import ArraySplits
    from repro.mapreduce import run_job_streaming
    with pytest.raises(ValueError):
        run_job_streaming(neighbor_search_job(0.1, tile=64),
                          ArraySplits(sky.make_catalog(50, 0), 2),
                          combiner="bogus")


def test_streaming_auto_combiner_requires_exact_codec():
    """int16 quantizes the combiner's count column into a different wire
    domain, so "auto" must NOT derive a combiner for lossy codecs."""
    from repro.data import ArraySplits
    from repro.mapreduce import run_job_streaming, token_histogram_job
    toks = np.random.default_rng(4).integers(0, 100, 3000)
    items = toks.astype(np.float32).reshape(-1, 1)
    res = run_job_streaming(token_histogram_job(100, codec="int16", tile=64),
                            ArraySplits(items, 3))
    assert res.stats.combiner == ""
    np.testing.assert_array_equal(res.output,
                                  np.bincount(toks, minlength=100))


@pytest.mark.slow
def test_streaming_matches_monolithic_on_mesh():
    """Streaming over 2/5/n-of-1 splits == monolithic on an 8-device data
    mesh, incl. wordcount with the combiner on/off (subprocess)."""
    script = os.path.join(os.path.dirname(__file__), "md_check.py")
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, script, "mapreduce-streaming"],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, (
        f"mapreduce-streaming failed:\n{r.stdout}\n{r.stderr}")
    assert "OK" in r.stdout


def test_codec_exact_flags():
    assert get_codec("identity").exact
    assert not get_codec("int16").exact and not get_codec("int8").exact


def test_codec_device_transforms_roundtrip():
    """decode_device(encode_device(x)) matches the host roundtrip exactly
    for identity/int16 (bit-exact wire contract), within error_bound for
    the per-row int8 device layout."""
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    x = rng.uniform(-1, 1, (257, 3)).astype(np.float32)
    for name in ("identity", "int16"):
        codec = get_codec(name)
        dev = np.asarray(codec.decode_device(*codec.encode_device(
            jnp.asarray(x))))
        np.testing.assert_array_equal(dev, codec.roundtrip(x))
    codec = get_codec("int8")
    dev = np.asarray(codec.decode_device(*codec.encode_device(
        jnp.asarray(x))))
    assert np.max(np.abs(dev - x)) <= codec.error_bound(x) + 1e-7
    assert codec.device_bytes_per_item(3) == 3 + 4      # int8 codes + scale


# ---------------------------------------------------------------------------
# StageStats -> RooflineTerms
# ---------------------------------------------------------------------------

def test_stage_stats_feed_roofline():
    xyz = sky.make_catalog(500, 4)
    res = run_job(neighbor_search_job(0.08, codec="int16", tile=64), xyz)
    st = res.stats
    assert st.n_items == 500 and st.codec == "int16"
    assert st.shuffle_wire_bytes > 0
    assert st.compression_ratio == pytest.approx(2.0)
    assert st.reduce_flops > 0 and st.reduce_bytes > 0
    assert st.dominant_stage in ("map", "shuffle", "reduce")
    terms = st.roofline(chips=1)
    d = terms.to_dict()                        # the paper's Table-4 columns
    for key in ("AD", "ADN", "dominant", "chips_to_balance"):
        assert key in d
    full = st.to_dict()
    assert full["amdahl"]["flops"] == st.reduce_flops


# ---------------------------------------------------------------------------
# Deprecated wrappers: old signatures still work and match the dense oracle
# ---------------------------------------------------------------------------

def test_deprecated_wrappers_match_dense_oracle():
    for seed, n, radius in [(0, 300, 0.05), (1, 500, 0.1), (2, 200, 0.2)]:
        xyz = sky.make_catalog(n, seed)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            got = neighbor_search_count(xyz, radius, tile=64)
        assert got == len(neighbor_pairs_dense(xyz, radius))

    xyz = sky.make_catalog(400, 5)
    edges_rad = np.linspace(0.02, 0.12, 6)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        h = neighbor_statistics(xyz, edges_arcsec=edges_rad / sky.ARCSEC,
                                tile=64)
    np.testing.assert_array_equal(
        h, sky.brute_force_hist(xyz, np.concatenate([[0], edges_rad])))


def test_wrappers_warn_deprecation():
    xyz = sky.make_catalog(60, 0)
    with pytest.warns(DeprecationWarning):
        neighbor_search_count(xyz, 0.1, tile=64)
    with pytest.warns(DeprecationWarning):
        neighbor_statistics(xyz, edges_arcsec=[10.0, 20.0], tile=64)


# ---------------------------------------------------------------------------
# Mesh parity (8 host devices, via subprocess like test_multidevice.py)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_mesh_matches_single_device():
    script = os.path.join(os.path.dirname(__file__), "md_check.py")
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, script, "mapreduce"],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"mapreduce check failed:\n{r.stdout}\n{r.stderr}"
    assert "OK" in r.stdout
