"""Decode-cache sharding specs must resolve as designed on the production mesh.

Regression guard for two §Perf findings: (1) schema-time divisibility checks see the
wrong mesh context (caches silently fell back to batch-only sharding -> 16x per-chip
cache), and (2) contraction-dim sharding makes GSPMD re-gather the cache per token.
This test resolves the specs the dry-run would use, without any device allocation.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, %r)
import jax
from jax.sharding import PartitionSpec as P
from repro.configs import get_arch
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import cache_schema
from repro.parallel.sharding import make_rules, spec_for, use_mesh

mesh = make_production_mesh()
rules = make_rules(mesh)
checks = {
    # arch: (group, layer, entry, leaf, expected sharded dims count > 1)
    "tinyllama-1.1b": ("g0", "l0", "attn", "k"),
    "gemma2-2b": ("g0", "l0", "attn", "k"),
    "musicgen-medium": ("g0", "l0", "attn", "k"),
    "internvl2-2b": ("g0", "l0", "attn", "k"),
    "olmo-1b": ("g0", "l0", "attn", "k"),
}
with use_mesh(mesh, rules):
    for arch, (g, l, e, leaf) in checks.items():
        cfg = get_arch(arch)
        sch = cache_schema(cfg, 128, 32768)
        pd = sch[g][l][e][leaf]
        spec = spec_for(pd.shape[1:], pd.dims[1:], mesh, rules)  # drop stack dim
        flat = [a for part in spec if part for a in
                (part if isinstance(part, tuple) else (part,))]
        # every cache must shard over BOTH a batch axis and the model axis
        assert "data" in flat, (arch, spec)
        assert "model" in flat, (arch, spec)
        # internvl2 opts into seq-sharding; others must not use seq
        pos_model = [i for i, part in enumerate(spec) if part and
                     ("model" == part or (isinstance(part, tuple) and "model" in part))]
        if arch == "internvl2-2b":
            assert pos_model == [1], (arch, spec)   # seq dim (after batch)
        else:
            assert pos_model != [1], (arch, spec)
print("cache specs OK")
"""


@pytest.mark.slow
def test_cache_specs_on_production_mesh():
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT % os.path.abspath(src)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "cache specs OK" in r.stdout
