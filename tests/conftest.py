import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own flags in a
# separate process); a persistent compilation cache makes repeat runs cheap.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_pytest_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def cpu_mesh():
    from repro.core.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture()
def rng():
    return jax.random.PRNGKey(0)
