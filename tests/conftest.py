import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own flags in a
# separate process); a persistent compilation cache makes repeat runs cheap.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_pytest_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)

import pytest  # noqa: E402

# ---------------------------------------------------------------------------
# Per-test timeout guard (hand-rolled: pytest-timeout is not a dependency).
# A deadlocked lane/pool/service thread must FAIL the test quickly instead of
# hanging the whole suite/CI until the job-level timeout. SIGALRM fires on
# the main thread, so even a test blocked on a lock/join raises. Default is
# generous (the md_check subprocess tests legitimately run for minutes);
# chaos tests tighten it per-test with @pytest.mark.timeout_s(N).
# ---------------------------------------------------------------------------
import signal      # noqa: E402
import threading   # noqa: E402

DEFAULT_TEST_TIMEOUT_S = int(os.environ.get("PYTEST_PER_TEST_TIMEOUT_S",
                                            "1200"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "timeout_s(seconds): per-test SIGALRM deadline (default "
        f"{DEFAULT_TEST_TIMEOUT_S}s; deadlocked threads fail fast)")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    mark = item.get_closest_marker("timeout_s")
    limit = int(mark.args[0]) if mark and mark.args else DEFAULT_TEST_TIMEOUT_S
    usable = (hasattr(signal, "SIGALRM")
              and threading.current_thread() is threading.main_thread()
              and limit > 0)
    if not usable:
        yield
        return

    def on_alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded its {limit}s timeout guard — "
            f"a lane/pool/service thread is likely deadlocked")

    old = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(limit)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="session")
def cpu_mesh():
    from repro.core.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture()
def rng():
    return jax.random.PRNGKey(0)
