"""Property tests for the LZO-analogue compression (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.core.compression import (
    compress_roundtrip, dequantize_block, ef_compress, quantize_block)

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


@given(n=st.integers(1, 2000), scale=st.floats(1e-3, 1e3), seed=st.integers(0, 99))
def test_quantization_error_bound(n, scale, seed):
    """|x - dq(q(x))| <= per-block max/127/2 + eps, elementwise."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n) * scale, jnp.float32)
    q, s, m = quantize_block(x)
    back = dequantize_block(q, s, m)
    block = 256
    pad = (-n) % block
    xp = np.pad(np.asarray(x), (0, pad)).reshape(-1, block)
    bound = np.abs(xp).max(axis=1, keepdims=True) / 127.0 * 0.51 + 1e-9
    err = np.abs(np.asarray(back) - np.asarray(x))
    errp = np.pad(err, (0, pad)).reshape(-1, block)
    assert np.all(errp <= bound)


@given(n=st.integers(1, 1000), seed=st.integers(0, 99))
def test_error_feedback_invariant(n, seed):
    """sent + new_err == g + old_err (nothing is lost, only delayed)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    err = jnp.asarray(rng.normal(size=n) * 0.01, jnp.float32)
    sent, new_err = ef_compress(g, err)
    lhs = np.asarray(sent, np.float64) + np.asarray(new_err, np.float64)
    rhs = np.asarray(g, np.float64) + np.asarray(err, np.float64)
    np.testing.assert_allclose(lhs, rhs, atol=1e-5)


@given(seed=st.integers(0, 99))
def test_error_feedback_converges(seed):
    """Repeatedly compressing the same gradient with EF: average of what was sent
    converges to the true gradient (residual stays bounded)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=512), jnp.float32)
    err = None
    sent_sum = np.zeros(512)
    T = 20
    for _ in range(T):
        sent, err = ef_compress(g, err)
        sent_sum += np.asarray(sent)
    avg = sent_sum / T
    resid = np.abs(np.asarray(err))
    scale = np.abs(np.asarray(g)).max()
    np.testing.assert_allclose(avg, np.asarray(g), atol=scale / 127.0 + 1e-3)
    assert resid.max() <= scale / 127.0 + 1e-5


def test_compress_roundtrip_shape_preserved(rng):
    x = jax.random.normal(rng, (3, 5, 7), jnp.bfloat16)
    y = compress_roundtrip(x)
    assert y.shape == x.shape and y.dtype == x.dtype
