"""Multi-device semantics checks (run as a subprocess with 8 host devices).

Usage: python tests/md_check.py <check-name>
Checks exit 0 on success; any assertion failure is a non-zero exit.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


from repro.core.compat import make_mesh, shard_map  # noqa: E402


def mesh3():
    return make_mesh((2, 2, 2), ("pod", "data", "model"))


def check_hierarchical_psum():
    from repro.core.collectives import hierarchical_psum_1d
    mesh = mesh3()
    x = jnp.arange(4 * 64, dtype=jnp.float32)      # [4 dp shards x 64] flattened

    def flat(v):
        return jax.lax.psum(v, ("pod", "data"))

    def hier(v):
        return hierarchical_psum_1d(v, "data", "pod")

    kw = dict(mesh=mesh, in_specs=P(("pod", "data")),
              out_specs=P(("pod", "data")),
              axis_names=frozenset({"pod", "data"}))
    o1 = jax.jit(shard_map(flat, **kw))(x)
    o2 = jax.jit(shard_map(hier, **kw))(x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)
    print("hierarchical == flat psum OK")


def check_compressed_psum():
    from repro.core.compression import compressed_psum_1d
    mesh = mesh3()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=4 * 512), jnp.float32)

    def comp(v):
        return compressed_psum_1d(v, "data")

    def flat(v):
        return jax.lax.psum(v, "data")

    spec = P(("pod", "data"))
    kw = dict(mesh=mesh, in_specs=spec, out_specs=spec,
              axis_names=frozenset({"pod", "data"}))
    o1 = jax.jit(shard_map(flat, **kw))(x)
    o2 = jax.jit(shard_map(comp, **kw))(x)
    err = np.abs(np.asarray(o1) - np.asarray(o2)).max()
    scale = np.abs(np.asarray(o1)).max()
    assert err <= scale * 0.03, (err, scale)
    print(f"compressed psum relerr={err/scale:.4f} OK")


def check_moe_multidevice():
    """Reduced granite MoE: 8-device EP result == 1-device result."""
    from repro.configs import get_arch
    import dataclasses
    from repro.models import moe as moe_mod
    from repro.parallel.sharding import init_params, make_rules, use_mesh
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     chunk_tokens=64))
    mesh1 = make_mesh((1, 1), ("data", "model"))
    mesh8 = make_mesh((4, 2), ("data", "model"))
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (4, 16, cfg.d_model), jnp.float32) * 0.5
    bias = jnp.zeros((cfg.moe.n_experts_padded,), jnp.float32)
    with use_mesh(mesh1):
        p = init_params(moe_mod.moe_schema(cfg), rng, dtype_override="float32")
        y1, _ = jax.jit(lambda p, x: moe_mod.moe_apply(cfg, p, x, bias))(p, x)
    with use_mesh(mesh8, make_rules(mesh8)):
        y8, _ = jax.jit(lambda p, x: moe_mod.moe_apply(cfg, p, x, bias))(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y8),
                               atol=2e-4, rtol=2e-4)
    print("MoE 8-device == 1-device OK")


def check_train_step_sharded():
    """Reduced tinyllama: 2 train steps on a (2,2,2) mesh run + loss finite,
    and the explicit replicated+compressed path matches the sharded path's loss."""
    from repro.configs import RunConfig, get_arch
    from repro.parallel.sharding import make_rules, use_mesh
    from repro.training.state import init_state
    from repro.training.step import make_train_step
    cfg = get_arch("tinyllama-1.1b").reduced()
    mesh = mesh3()
    losses = {}
    for name, rc in {
        "sharded": RunConfig(remat="none", pod_param_mode="sharded"),
        "explicit": RunConfig(remat="none", pod_param_mode="replicated",
                              compress_grads=True, hierarchical_sync=True,
                              bucketed_updates=True),
    }.items():
        step_fn, _, _, rules = make_train_step(cfg, rc, mesh)
        with use_mesh(mesh, rules):
            state = init_state(cfg, rc, jax.random.PRNGKey(0), mesh)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
        batch = {"tokens": toks}
        for _ in range(2):
            state, mets = step_fn(state, batch)
        losses[name] = float(mets["loss"])
        assert np.isfinite(losses[name])
    # both modes train on identical data from identical init: losses close
    assert abs(losses["sharded"] - losses["explicit"]) < 0.15, losses
    print(f"train modes OK: {losses}")


def check_mapreduce_device_sharded():
    """Sharded DEVICE engine: on an 8-device data mesh, engine="device"
    (tier arrays sharded over ``data``, psum tier combine) must match the
    host-engine mesh oracle BIT-EXACTLY for exact codecs, across the ragged
    shard shapes that stress the phantom-partition padding:

    - both shuffle index paths ("jnp" and "host") -> identical metadata,
    - the traceable in-shard_map path (pure-jnp wordcount reducer, and the
      pair kernels forced through Pallas interpret mode).

    The ragged shard shapes (non-divisible tier counts, single-shard tiers,
    zero-entry partitions, empty catalog) live in ``mapreduce-ragged``.
    """
    from repro.core.compat import make_mesh as mk
    from repro.data import sky
    from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                                 neighbor_statistics_job, run_job, run_jobs,
                                 token_histogram)
    from repro.mapreduce import job as job_mod

    mesh = mk((8,), ("data",))

    # batched paper apps, identity + int16
    for codec in ("identity", "int16"):
        xyz = sky.make_catalog(1200, 7)
        radius = 0.1
        part = ZonePartitioner(radius)
        edges = np.linspace(0.02, radius, 5)
        jobs = [neighbor_search_job(radius, partitioner=part, tile=64,
                                    codec=codec),
                neighbor_statistics_job(edges / sky.ARCSEC, codec=codec,
                                        partitioner=part, tile=64)]
        rd = run_jobs(jobs, xyz, mesh=mesh, engine="device")
        rh = run_jobs(jobs, xyz, mesh=mesh, engine="host")
        r1 = run_jobs(jobs, xyz, engine="device")
        assert rd[0].output == rh[0].output == r1[0].output, (
            codec, rd[0].output, rh[0].output, r1[0].output)
        np.testing.assert_array_equal(rd[1].output, rh[1].output)
        np.testing.assert_array_equal(rd[1].output, r1[1].output)
        if codec == "identity":
            assert rd[0].output == sky.brute_force_pairs(xyz, radius)
        st = rd[0].stats
        assert st.engine == "device" and st.n_shards == 8
        assert len(st.shard_padded_ratio) == 8

    # engine="auto" now picks device on a data mesh
    ra = run_job(neighbor_search_job(0.1, tile=64), xyz, mesh=mesh)
    assert ra.stats.engine == "device"
    assert ra.output == sky.brute_force_pairs(xyz, 0.1)

    # wordcount on the mesh: the traceable pure-jnp in-shard_map path
    toks = np.random.default_rng(1).integers(0, 500, 4000)
    hd = token_histogram(toks, 500, n_partitions=8, tile=64, mesh=mesh,
                         engine="device").output
    hh = token_histogram(toks, 500, n_partitions=8, tile=64, mesh=mesh,
                         engine="host").output
    np.testing.assert_array_equal(hd, hh)
    np.testing.assert_array_equal(hd, np.bincount(toks, minlength=500))

    # both shuffle index impls produce identical results AND metadata
    xyz = sky.make_catalog(700, 3)
    j = neighbor_search_job(0.09, codec="int16", tile=64)
    want = run_job(j, xyz, mesh=mesh, engine="device")
    old = job_mod.SHUFFLE_INDEX_IMPL
    job_mod.SHUFFLE_INDEX_IMPL = "jnp"
    try:
        got = run_job(j, xyz, mesh=mesh, engine="device")
    finally:
        job_mod.SHUFFLE_INDEX_IMPL = old
    assert got.output == want.output
    assert want.stats.shuffle_index_impl == "host"      # CPU backend default
    assert got.stats.shuffle_index_impl == "jnp"
    for f in ("shuffle_wire_bytes", "n_partitions", "reduce_padded_ratio",
              "shard_padded_ratio", "reduce_bytes"):
        assert getattr(got.stats, f) == getattr(want.stats, f), f

    # traceable in-shard_map path: pair kernels through Pallas interpret,
    # single job AND batched (two reducers fused in one shard_map region)
    xyz = sky.make_catalog(400, 7)
    part = ZonePartitioner(0.1)
    edges = np.linspace(0.03, 0.1, 4)
    jobs_pl = [neighbor_search_job(0.1, partitioner=part, tile=64,
                                   use_pallas=True),
               neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                       tile=64, use_pallas=True)]
    jobs_bk = [neighbor_search_job(0.1, partitioner=part, tile=64),
               neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                       tile=64)]
    rp = run_jobs(jobs_pl, xyz, mesh=mesh, engine="device")
    rb = run_jobs(jobs_bk, xyz, mesh=mesh, engine="device")
    assert rp[0].output == rb[0].output
    np.testing.assert_array_equal(rp[1].output, rb[1].output)
    print("mapreduce sharded-device == host mesh oracle OK")


def check_mapreduce_ragged_shards():
    """Ragged shard shapes on an 8-device data mesh, sharded device engine
    vs the host mesh oracle (bit-exact):

    - tier partition counts not divisible by the data axis size (a 0.25-rad
      zone layout gives ~13 zones over 8 shards),
    - a skewed catalog whose crowded tier has fewer real partitions than
      shards (the tier lands entirely on one shard; the rest are phantoms),
    - zero-entry partitions (wordcount vocab < n_partitions, so five
      partitions own nothing) and the zero-partition/empty-catalog case.
    """
    from repro.core.compat import make_mesh as mk
    from repro.data import sky
    from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                                 neighbor_statistics_job, plan_tiers,
                                 run_job, token_histogram)

    mesh = mk((8,), ("data",))

    # tier counts not divisible by 8
    for codec in ("identity", "int16"):
        for n, seed, radius in [(700, 3, 0.09), (150, 1, 0.25)]:
            xyz = sky.make_catalog(n, seed)
            j = neighbor_search_job(radius, codec=codec, tile=64)
            d = run_job(j, xyz, mesh=mesh, engine="device").output
            h = run_job(j, xyz, mesh=mesh, engine="host").output
            assert d == h, (codec, n, d, h)

    # skewed catalog: crowded tier has fewer real partitions than shards
    rng = np.random.default_rng(11)
    sk = sky.make_catalog(900, 1)
    extra = sk[:1] + rng.normal(0, 1e-3, (600, 3))
    sk = np.concatenate([sk, extra])
    sk = (sk / np.linalg.norm(sk, axis=1, keepdims=True)).astype(np.float32)
    j = neighbor_search_job(0.08, tile=64)
    assert (run_job(j, sk, mesh=mesh, engine="device").output
            == run_job(j, sk, mesh=mesh, engine="host").output)
    part = ZonePartitioner(0.08)
    keys = part.assign(sk)
    no = np.bincount(keys, minlength=part.n_partitions(sk))
    plan = plan_tiers(no, no * 2, 64, pad_partitions_to=8)
    assert any(len(ids) < 8 for ids, _, _ in plan), (
        "skew did not produce a sub-shard tier")

    # zero-entry partitions + empty catalog
    toks = np.random.default_rng(0).integers(0, 3, 1000)
    hd = token_histogram(toks, 3, n_partitions=8, tile=64, mesh=mesh,
                         engine="device").output
    hh = token_histogram(toks, 3, n_partitions=8, tile=64, mesh=mesh,
                         engine="host").output
    np.testing.assert_array_equal(hd, hh)
    np.testing.assert_array_equal(hd, np.bincount(toks, minlength=3))
    xyz0 = np.zeros((0, 3), np.float32)
    assert run_job(neighbor_search_job(0.05, tile=64), xyz0, mesh=mesh,
                   engine="device").output == 0
    np.testing.assert_array_equal(
        run_job(neighbor_statistics_job([10.0, 20.0], tile=64), xyz0,
                mesh=mesh, engine="device").output, [0, 0])
    print("mapreduce ragged shards == host mesh oracle OK")


def check_mapreduce_streaming_sharded():
    """Split-streaming executor on an 8-device data mesh: streaming over
    2 and 5 splits (and n-splits-of-1 for a small catalog) is bit-identical
    to the monolithic mesh run for the batched paper apps (identity and
    int16 codecs — no combiner exists for pair kernels, so the accumulated
    wire streams cross one sharded reduce) and for wordcount with the
    map-side combiner on, off, and auto (per-split psum-sharded reduce,
    cross-split combine on the replicated partial)."""
    from repro.core.compat import make_mesh as mk
    from repro.data import ArraySplits, sky
    from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                                 neighbor_statistics_job, run_job_streaming,
                                 run_jobs, run_jobs_streaming,
                                 token_histogram_job)

    mesh = mk((8,), ("data",))
    radius = 0.09
    edges = np.linspace(0.03, radius, 4)
    for codec in ("identity", "int16"):
        part = ZonePartitioner(radius)
        jobs = [neighbor_search_job(radius, partitioner=part, codec=codec,
                                    tile=64),
                neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                        codec=codec, tile=64)]
        xyz = sky.make_catalog(900, 5)
        mono = run_jobs(jobs, xyz, mesh=mesh)
        for n_splits in (2, 5):
            srun = run_jobs_streaming(jobs, ArraySplits(xyz, n_splits),
                                      mesh=mesh)
            assert srun[0].stats.n_splits == n_splits
            assert srun[0].output == mono[0].output, (codec, n_splits)
            np.testing.assert_array_equal(srun[1].output, mono[1].output)
        small = xyz[:40]
        mono_s = run_jobs(jobs, small, mesh=mesh)
        ones = run_jobs_streaming(jobs, ArraySplits(small, 40), mesh=mesh)
        assert ones[0].output == mono_s[0].output, codec
        np.testing.assert_array_equal(ones[1].output, mono_s[1].output)

    toks = np.random.default_rng(2).integers(0, 300, 6000)
    items = toks.astype(np.float32).reshape(-1, 1)
    job = token_histogram_job(300, n_partitions=16, tile=64)
    want = np.bincount(toks, minlength=300)
    for combiner in (None, "auto", job.reducer.combiner()):
        res = run_job_streaming(job, ArraySplits(items, 4), mesh=mesh,
                                combiner=combiner)
        np.testing.assert_array_equal(res.output, want)
        if combiner is not None:
            assert res.stats.combiner == "token_count"
    print("mapreduce streaming == monolithic on 8-shard mesh OK")


def check_mapreduce_sharded():
    """Job engine: sharded-mesh results == mesh=None results, for both paper
    apps (batched over one shuffle) and the wordcount job."""
    from repro.data import sky
    from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                                 neighbor_statistics_job, run_jobs,
                                 token_histogram)
    mesh = make_mesh((4, 2), ("data", "model"))
    xyz = sky.make_catalog(1200, 7)
    radius = 0.1
    part = ZonePartitioner(radius)
    edges = np.linspace(0.02, radius, 5)
    jobs = [neighbor_search_job(radius, partitioner=part, tile=64),
            neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                    tile=64)]
    r1 = run_jobs(jobs, xyz, mesh=None)
    r8 = run_jobs(jobs, xyz, mesh=mesh)
    assert r1[0].output == r8[0].output, (r1[0].output, r8[0].output)
    np.testing.assert_array_equal(r1[1].output, r8[1].output)
    assert r8[0].output == sky.brute_force_pairs(xyz, radius)

    toks = np.random.default_rng(1).integers(0, 500, 4000)
    h1 = token_histogram(toks, 500, n_partitions=8, tile=64).output
    h8 = token_histogram(toks, 500, n_partitions=8, tile=64,
                         mesh=mesh).output
    np.testing.assert_array_equal(h1, h8)
    np.testing.assert_array_equal(h1, np.bincount(toks, minlength=500))
    print("mapreduce sharded == single-device OK")


def check_mapreduce_service_sharded():
    """MR query service on an 8-device data mesh: queries served from the
    resident psum-sharded catalog (micro-batched, duplicates coalesced) are
    bit-identical to a fresh per-query mesh run AND to the host-engine
    oracle; catalog reuse across batches never reshuffles."""
    from repro.core.compat import make_mesh as mk
    from repro.data import sky
    from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                                 neighbor_statistics_job, run_job)
    from repro.serving.mr_service import MRQueryService

    mesh = mk((8,), ("data",))
    xyz = sky.make_catalog(900, 5)
    radius = 0.09
    part = ZonePartitioner(radius)
    edges = np.linspace(0.03, radius, 4)
    jobs = [neighbor_search_job(radius, partitioner=part, codec="int16",
                                tile=64),
            neighbor_search_job(radius / 2, partitioner=part, codec="int16",
                                tile=64),
            neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                    codec="int16", tile=64)]
    svc = MRQueryService(mesh=mesh, max_batch=4)
    cat = svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    assert cat.run(jobs[0])[0].stats.n_shards == 8
    reqs = [svc.submit(j, catalog="sky") for j in jobs + jobs]
    svc.run_pending()                  # batches of 4: [j0 j1 j2 j0] [j1 j2]
    assert [b["size"] for b in svc.batches] == [4, 2]
    assert svc.batches[0]["n_unique"] == 3       # duplicate j0 coalesced
    for r, j in zip(reqs, jobs + jobs):
        dev = run_job(j, xyz, mesh=mesh).output
        host = run_job(j, xyz, mesh=mesh, engine="host").output
        np.testing.assert_array_equal(r.output, dev)
        np.testing.assert_array_equal(np.asarray(dev), np.asarray(host))
    svc.close()
    print("mapreduce service on 8-shard mesh == per-query mesh/host OK")


def check_mapreduce_lanes_sharded():
    """Concurrent split lanes across 8 host devices: with no mesh, each
    lane pins its worker to devices[lane % n_devices] so independent splits
    map/shuffle/reduce on different devices concurrently — results must be
    bit-identical to the monolithic single-device run, with injected chaos
    (seeded delays + transient faults + speculation) and without."""
    from repro.data import ArraySplits, sky
    from repro.ft import FaultySplitSource, SpeculativeConfig
    from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                                 run_job, run_job_streaming,
                                 token_histogram_job)

    assert len(jax.devices()) == 8
    radius = 0.09
    part = ZonePartitioner(radius)
    job = neighbor_search_job(radius, partitioner=part, codec="int16",
                              tile=64)
    xyz = sky.make_catalog(900, 5)
    want = run_job(job, xyz).output

    # plain per-device lanes, one lane per host device
    res = run_job_streaming(job, ArraySplits(xyz, 8), n_lanes=8)
    assert res.output == want
    assert res.stats.n_lanes == 8 and len(res.stats.lane_walls) == 8

    # chaos on top: seeded delays + transient faults + speculation
    src = FaultySplitSource(ArraySplits(xyz, 8), seed=0, delay_p=0.4,
                            fault_p=0.4, delay_s=0.05, max_faults=2)
    res2 = run_job_streaming(
        job, src, n_lanes=8, max_retries=2, retry_backoff_s=0.01,
        speculate=SpeculativeConfig(slowdown=2.0, min_finished=2))
    assert res2.output == want

    # wordcount combine mode across lanes (order-free monoid merge)
    toks = np.random.default_rng(2).integers(0, 300, 6000)
    items = toks.astype(np.float32).reshape(-1, 1)
    wjob = token_histogram_job(300, n_partitions=16, tile=64)
    wres = run_job_streaming(wjob, ArraySplits(items, 8), n_lanes=8)
    np.testing.assert_array_equal(wres.output,
                                  np.bincount(toks, minlength=300))
    print("mapreduce lanes across 8 devices == monolithic OK")


if __name__ == "__main__":
    checks = {
        "hier": check_hierarchical_psum,
        "compressed": check_compressed_psum,
        "moe": check_moe_multidevice,
        "train": check_train_step_sharded,
        "mapreduce": check_mapreduce_sharded,
        "mapreduce-device": check_mapreduce_device_sharded,
        "mapreduce-ragged": check_mapreduce_ragged_shards,
        "mapreduce-streaming": check_mapreduce_streaming_sharded,
        "mapreduce-service": check_mapreduce_service_sharded,
        "mapreduce-lanes": check_mapreduce_lanes_sharded,
    }
    checks[sys.argv[1]]()
