"""Multi-device semantics checks (run as a subprocess with 8 host devices).

Usage: python tests/md_check.py <check-name>
Checks exit 0 on success; any assertion failure is a non-zero exit.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


from repro.core.compat import make_mesh, shard_map  # noqa: E402


def mesh3():
    return make_mesh((2, 2, 2), ("pod", "data", "model"))


def check_hierarchical_psum():
    from repro.core.collectives import hierarchical_psum_1d
    mesh = mesh3()
    x = jnp.arange(4 * 64, dtype=jnp.float32)      # [4 dp shards x 64] flattened

    def flat(v):
        return jax.lax.psum(v, ("pod", "data"))

    def hier(v):
        return hierarchical_psum_1d(v, "data", "pod")

    kw = dict(mesh=mesh, in_specs=P(("pod", "data")),
              out_specs=P(("pod", "data")),
              axis_names=frozenset({"pod", "data"}))
    o1 = jax.jit(shard_map(flat, **kw))(x)
    o2 = jax.jit(shard_map(hier, **kw))(x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)
    print("hierarchical == flat psum OK")


def check_compressed_psum():
    from repro.core.compression import compressed_psum_1d
    mesh = mesh3()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=4 * 512), jnp.float32)

    def comp(v):
        return compressed_psum_1d(v, "data")

    def flat(v):
        return jax.lax.psum(v, "data")

    spec = P(("pod", "data"))
    kw = dict(mesh=mesh, in_specs=spec, out_specs=spec,
              axis_names=frozenset({"pod", "data"}))
    o1 = jax.jit(shard_map(flat, **kw))(x)
    o2 = jax.jit(shard_map(comp, **kw))(x)
    err = np.abs(np.asarray(o1) - np.asarray(o2)).max()
    scale = np.abs(np.asarray(o1)).max()
    assert err <= scale * 0.03, (err, scale)
    print(f"compressed psum relerr={err/scale:.4f} OK")


def check_moe_multidevice():
    """Reduced granite MoE: 8-device EP result == 1-device result."""
    from repro.configs import get_arch
    import dataclasses
    from repro.models import moe as moe_mod
    from repro.parallel.sharding import init_params, make_rules, use_mesh
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     chunk_tokens=64))
    mesh1 = make_mesh((1, 1), ("data", "model"))
    mesh8 = make_mesh((4, 2), ("data", "model"))
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (4, 16, cfg.d_model), jnp.float32) * 0.5
    bias = jnp.zeros((cfg.moe.n_experts_padded,), jnp.float32)
    with use_mesh(mesh1):
        p = init_params(moe_mod.moe_schema(cfg), rng, dtype_override="float32")
        y1, _ = jax.jit(lambda p, x: moe_mod.moe_apply(cfg, p, x, bias))(p, x)
    with use_mesh(mesh8, make_rules(mesh8)):
        y8, _ = jax.jit(lambda p, x: moe_mod.moe_apply(cfg, p, x, bias))(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y8),
                               atol=2e-4, rtol=2e-4)
    print("MoE 8-device == 1-device OK")


def check_train_step_sharded():
    """Reduced tinyllama: 2 train steps on a (2,2,2) mesh run + loss finite,
    and the explicit replicated+compressed path matches the sharded path's loss."""
    from repro.configs import RunConfig, get_arch
    from repro.parallel.sharding import make_rules, use_mesh
    from repro.training.state import init_state
    from repro.training.step import make_train_step
    cfg = get_arch("tinyllama-1.1b").reduced()
    mesh = mesh3()
    losses = {}
    for name, rc in {
        "sharded": RunConfig(remat="none", pod_param_mode="sharded"),
        "explicit": RunConfig(remat="none", pod_param_mode="replicated",
                              compress_grads=True, hierarchical_sync=True,
                              bucketed_updates=True),
    }.items():
        step_fn, _, _, rules = make_train_step(cfg, rc, mesh)
        with use_mesh(mesh, rules):
            state = init_state(cfg, rc, jax.random.PRNGKey(0), mesh)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
        batch = {"tokens": toks}
        for _ in range(2):
            state, mets = step_fn(state, batch)
        losses[name] = float(mets["loss"])
        assert np.isfinite(losses[name])
    # both modes train on identical data from identical init: losses close
    assert abs(losses["sharded"] - losses["explicit"]) < 0.15, losses
    print(f"train modes OK: {losses}")


def check_mapreduce_sharded():
    """Job engine: sharded-mesh results == mesh=None results, for both paper
    apps (batched over one shuffle) and the wordcount job."""
    from repro.data import sky
    from repro.mapreduce import (ZonePartitioner, neighbor_search_job,
                                 neighbor_statistics_job, run_jobs,
                                 token_histogram)
    mesh = make_mesh((4, 2), ("data", "model"))
    xyz = sky.make_catalog(1200, 7)
    radius = 0.1
    part = ZonePartitioner(radius)
    edges = np.linspace(0.02, radius, 5)
    jobs = [neighbor_search_job(radius, partitioner=part, tile=64),
            neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                    tile=64)]
    r1 = run_jobs(jobs, xyz, mesh=None)
    r8 = run_jobs(jobs, xyz, mesh=mesh)
    assert r1[0].output == r8[0].output, (r1[0].output, r8[0].output)
    np.testing.assert_array_equal(r1[1].output, r8[1].output)
    assert r8[0].output == sky.brute_force_pairs(xyz, radius)

    toks = np.random.default_rng(1).integers(0, 500, 4000)
    h1 = token_histogram(toks, 500, n_partitions=8, tile=64).output
    h8 = token_histogram(toks, 500, n_partitions=8, tile=64,
                         mesh=mesh).output
    np.testing.assert_array_equal(h1, h8)
    np.testing.assert_array_equal(h1, np.bincount(toks, minlength=500))
    print("mapreduce sharded == single-device OK")


if __name__ == "__main__":
    checks = {
        "hier": check_hierarchical_psum,
        "compressed": check_compressed_psum,
        "moe": check_moe_multidevice,
        "train": check_train_step_sharded,
        "mapreduce": check_mapreduce_sharded,
    }
    checks[sys.argv[1]]()
