"""RG-LRU: associative scan vs sequential loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.rglru import rglru_apply, rglru_decode, _gates
from repro.parallel.sharding import init_params, use_mesh
from repro.models.rglru import rglru_schema


def test_scan_matches_sequential(rng, cpu_mesh):
    cfg = get_arch("recurrentgemma-2b").reduced()
    with use_mesh(cpu_mesh):
        p = init_params(rglru_schema(cfg), rng)
    B, L, D = 2, 24, cfg.d_model
    x = jax.random.normal(rng, (B, L, D), jnp.float32) * 0.5

    with use_mesh(cpu_mesh):
        y, cache = rglru_apply(cfg, p, x, make_cache=True)

        # sequential oracle via repeated decode steps
        c = {"conv": jnp.zeros((B, cfg.rglru.conv_width - 1,
                                cfg.rglru.lru_width or D)),
             "state": jnp.zeros((B, cfg.rglru.lru_width or D), jnp.float32)}
        outs = []
        for t in range(L):
            o, c = rglru_decode(cfg, p, x[:, t:t + 1], c, jnp.int32(t))
            outs.append(o[:, 0])
        y_seq = jnp.stack(outs, axis=1)

    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_seq, np.float32),
                               atol=2e-3, rtol=2e-3)
    # cache state must match the sequential final state
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(c["state"]), atol=2e-3, rtol=2e-3)


def test_gates_bounded(rng):
    cfg = get_arch("recurrentgemma-2b").reduced()
    with use_mesh(None):
        pass
    p = init_params(rglru_schema(cfg), rng)
    u = jax.random.normal(rng, (4, 8, cfg.rglru.lru_width or cfg.d_model))
    a, b = _gates(cfg, p, u)
    assert bool(jnp.all((a > 0) & (a < 1)))
    assert bool(jnp.all(jnp.isfinite(b)))
