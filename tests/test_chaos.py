"""Chaos parity: the lane scheduler under injected faults.

The fault-tolerance claim is only real if recovery is *invisible in the
results*: streaming==monolithic bit parity must survive concurrent lanes,
seeded delays, transient fetch faults with retry, speculative clones winning
AND losing, and lane deaths. Every test here asserts exact equality against
the monolithic oracle — recovery that changes the answer is a bug, not a
degraded mode.

Seeded randomized cases read ``CHAOS_SEED`` (the CI seed matrix re-runs this
file under several seeds); the hypothesis property (skipped when hypothesis
is not installed — CI installs it) additionally fuzzes lane counts, split
boundaries, and fault schedules.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.data import sky
from repro.data.pipeline import ArraySplits
from repro.ft import (FaultySplitSource, LaneChaos, SpeculativeConfig,
                      SpeculativePolicy, TransientSplitError)
from repro.mapreduce import (JobDeadlineExceeded, LanePool,
                             neighbor_search_job, run_job, run_job_streaming,
                             token_histogram_job)

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))

RADIUS = 0.02


def _catalog(n=3000, seed=0):
    return sky.make_catalog(n, seed=seed)


def _tokens(n=4000, vocab=89):
    return (np.arange(n) % vocab).astype(np.float32).reshape(-1, 1)


# ---------------------------------------------------------------------------
# parity under lanes / faults / speculation
# ---------------------------------------------------------------------------

@pytest.mark.timeout_s(300)
@pytest.mark.parametrize("n_lanes", [1, 2, 4])
def test_lanes_bit_parity_search(n_lanes):
    """Concurrent lanes == monolithic, accumulate mode (pair search)."""
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    res = run_job_streaming(job, ArraySplits(xyz, n_splits=5),
                            n_lanes=n_lanes)
    assert res.output == want
    assert res.stats.n_lanes == n_lanes
    if n_lanes > 1:        # n_lanes=1 without faults takes the sequential path
        assert len(res.stats.lane_walls) == n_lanes
    assert len(res.stats.splits) == 5
    assert [r["split"] for r in res.stats.splits] == list(range(5))


@pytest.mark.timeout_s(300)
def test_lanes_bit_parity_wordcount_combine():
    """Concurrent lanes == monolithic, combine mode (token histogram) —
    commit order is nondeterministic, sums must not care."""
    toks = _tokens()
    job = token_histogram_job(89)
    want = run_job(job, toks).output
    res = run_job_streaming(job, ArraySplits(toks, n_splits=6), n_lanes=3)
    assert np.array_equal(res.output, want)
    assert res.stats.combiner == "token_count"


@pytest.mark.timeout_s(300)
def test_transient_faults_retry_to_parity():
    """A fetch that fails transiently n times succeeds within a retry
    budget of n — and the retried run is bit-identical."""
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    src = FaultySplitSource(ArraySplits(xyz, n_splits=6),
                            faults={1: 2, 4: 1})
    res = run_job_streaming(job, src, n_lanes=2, max_retries=2,
                            retry_backoff_s=0.01)
    assert res.output == want
    assert res.stats.retries == 3
    assert src.injected_faults == 3


@pytest.mark.timeout_s(120)
def test_retry_budget_exhausted_raises():
    xyz = _catalog(800)
    job = neighbor_search_job(RADIUS, tile=128)
    src = FaultySplitSource(ArraySplits(xyz, n_splits=4), faults={2: 3})
    with pytest.raises(TransientSplitError):
        run_job_streaming(job, src, n_lanes=2, max_retries=2,
                          retry_backoff_s=0.01)


@pytest.mark.timeout_s(300)
def test_speculation_clone_wins_bit_parity():
    """A 1.5s straggler split gets cloned; the clone's re-fetch is fast and
    WINS; the stalled original is cancelled — same answer, real recovery."""
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    src = FaultySplitSource(ArraySplits(xyz, n_splits=8), delays={0: 1.5})
    pol = SpeculativePolicy(SpeculativeConfig(slowdown=2.0, min_finished=2,
                                              max_clones=1))
    res = run_job_streaming(job, src, n_lanes=2, speculate=pol)
    st = res.stats
    assert res.output == want
    assert st.speculated >= 1 and st.clone_wins >= 1
    assert st.elapsed_s < 1.5          # did NOT serve out the injected stall
    winner = st.splits[0]
    assert winner["split"] == 0 and winner["clone"]


@pytest.mark.timeout_s(300)
def test_speculation_clone_loses_bit_parity():
    """When the slowness is data-bound (every attempt pays the delay), the
    earlier-started original wins, the clone is cancelled, and the result
    is still bit-identical — first-finisher-wins is safe both ways."""
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    src = FaultySplitSource(ArraySplits(xyz, n_splits=6),
                            delays={0: 1.2}, delay_calls={0: 99})
    pol = SpeculativePolicy(SpeculativeConfig(slowdown=2.0, min_finished=2,
                                              max_clones=1))
    res = run_job_streaming(job, src, n_lanes=2, speculate=pol)
    st = res.stats
    assert res.output == want
    assert st.speculated >= 1
    assert st.clone_wins == 0
    assert not st.splits[0]["clone"]


@pytest.mark.timeout_s(300)
def test_speculation_on_vs_off_identical():
    """The acceptance property: identical fault schedule, speculation +
    retry ON vs OFF, bit-identical outputs (fresh sources so injected
    fault state doesn't leak between runs)."""
    xyz = _catalog()
    toks = _tokens()
    sjob = neighbor_search_job(RADIUS, tile=128)
    wjob = token_histogram_job(89)

    def faulty(items):
        return FaultySplitSource(ArraySplits(items, n_splits=6),
                                 delays={1: 0.4}, faults={3: 1},
                                 seed=CHAOS_SEED, fault_p=0.2)

    s_off = run_job_streaming(sjob, faulty(xyz), n_lanes=2, max_retries=3,
                              retry_backoff_s=0.01)
    s_on = run_job_streaming(
        sjob, faulty(xyz), n_lanes=3, max_retries=3, retry_backoff_s=0.01,
        speculate=SpeculativeConfig(slowdown=2.0, min_finished=2))
    assert s_on.output == s_off.output == run_job(sjob, xyz).output

    w_off = run_job_streaming(wjob, faulty(toks), n_lanes=2, max_retries=3,
                              retry_backoff_s=0.01)
    w_on = run_job_streaming(
        wjob, faulty(toks), n_lanes=3, max_retries=3, retry_backoff_s=0.01,
        speculate=SpeculativeConfig(slowdown=2.0, min_finished=2))
    assert np.array_equal(w_on.output, w_off.output)
    assert np.array_equal(w_on.output, run_job(wjob, toks).output)


@pytest.mark.timeout_s(300)
def test_lane_death_requeues_and_shrinks():
    """An injected lane death re-dispatches the lane's split onto the
    survivors; the run completes with parity and records the death."""
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    chaos = LaneChaos(kills=[(0, 1)])
    res = run_job_streaming(job, ArraySplits(xyz, n_splits=6), n_lanes=3,
                            chaos=chaos)
    assert res.output == want
    assert len(chaos.deaths) == 1


@pytest.mark.timeout_s(300)
def test_lane_killed_mid_spill_write_retries_to_parity(tmp_path):
    """PR8 chaos case: a lane dies mid-spill-segment-write. The torn staged
    segment is length-invalid (never committed, swept later), the split is
    retried on the survivors, and the spilled run stays bit-identical to
    the monolithic oracle — spill staging rides the existing retry ladder."""
    import tempfile

    from repro.mapreduce import SpillConfig

    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    state = {"n": 0, "torn": None}
    lock = threading.Lock()

    def kill_second_write(path):
        with lock:
            state["n"] += 1
            if state["n"] == 2:
                state["torn"] = path
                raise OSError("lane died mid-spill-write")

    root = tempfile.mkdtemp(prefix="chaos-spill-")
    res = run_job_streaming(
        job, ArraySplits(xyz, n_splits=6), n_lanes=3, max_retries=2,
        retry_backoff_s=0.01,
        spill=SpillConfig(budget_bytes=0, dir=root,
                          write_fault=kill_second_write))
    assert res.output == want
    assert res.stats.retries >= 1                 # the death was retried
    assert state["torn"] is not None and ".staged-" in state["torn"]
    assert res.stats.spilled_splits == 6          # all splits spilled in the end
    assert not os.path.exists(root)               # segments reclaimed


@pytest.mark.timeout_s(120)
def test_deadline_raises_instead_of_hanging():
    xyz = _catalog(800)
    job = neighbor_search_job(RADIUS, tile=128)
    src = FaultySplitSource(ArraySplits(xyz, n_splits=4), delays={0: 30.0})
    t0 = time.perf_counter()
    with pytest.raises(JobDeadlineExceeded):
        run_job_streaming(job, src, n_lanes=2, deadline_s=0.5)
    assert time.perf_counter() - t0 < 10.0   # cancelled, not served out


# ---------------------------------------------------------------------------
# LanePool unit behavior
# ---------------------------------------------------------------------------

@pytest.mark.timeout_s(60)
def test_lanepool_first_commit_wins_and_cancels():
    """Two attempts for one key: the first commit wins, the loser's cancel
    event fires and its (late) commit is dropped."""
    events = {"cancelled": 0}
    started = threading.Event()

    def slow(cancel):
        started.set()
        deadline = time.perf_counter() + 5.0
        while not cancel.is_set() and time.perf_counter() < deadline:
            time.sleep(0.005)
        if cancel.is_set():
            events["cancelled"] += 1
            from repro.mapreduce import LaneCancelled
            raise LaneCancelled(0)
        return "slow"

    def fast(cancel):
        return "fast"

    with LanePool(2, max_retries=0) as pool:
        pool.submit(0, slow)
        started.wait(2.0)
        pool.submit(0, fast, clone=True)
        pool.drain([0])
        assert pool.results[0] == "fast"
        assert pool.meta[0]["clone"]
        assert pool.clone_wins == 1
    assert events["cancelled"] == 1


@pytest.mark.timeout_s(60)
def test_lanepool_stuck_lane_declared_dead_and_requeued():
    """A lane wedged past ``stuck_after_s`` is declared dead through the
    Coordinator heartbeat/remesh machine; its split requeues and completes
    on a survivor; the pool records the remesh and shrinks."""
    wedged = threading.Event()

    def maybe_wedge(k):
        def fn(cancel):
            if k == 0 and not wedged.is_set():
                wedged.set()
                # ignore cancel for a while: a genuinely stuck task (bounded
                # so the daemon thread exits before interpreter teardown)
                time.sleep(3.0)
                from repro.mapreduce import LaneCancelled
                raise LaneCancelled(k)
            return k * 10
        return fn

    with LanePool(2, max_retries=0, stuck_after_s=0.2,
                  join_timeout_s=10.0) as pool:
        for k in range(4):
            pool.submit(k, maybe_wedge(k))
        pool.drain(range(4))
        assert {k: pool.results[k] for k in range(4)} == \
            {0: 0, 1: 10, 2: 20, 3: 30}
        assert pool.remeshes, "stuck lane never declared dead"
        assert pool.width == 1
    # the wedged thread was joined by shutdown (it wakes within 3s)


@pytest.mark.timeout_s(60)
def test_lanepool_shutdown_reports_leaked_thread():
    """A task that ignores cancellation past the join timeout is reported,
    not silently leaked (the no-leaked-threads exit guarantee)."""
    release = threading.Event()

    def stubborn(cancel):
        release.wait(20.0)
        return "late"

    pool = LanePool(1, max_retries=0, join_timeout_s=0.2)
    pool.submit(0, stubborn)
    time.sleep(0.1)
    with pytest.raises(RuntimeError, match="leaked lane thread"):
        pool.shutdown()
    release.set()                       # let the daemon thread exit cleanly
    pool.lanes[0].thread.join(timeout=5.0)


# ---------------------------------------------------------------------------
# seeded randomized parity (the CI seed matrix re-runs these per seed)
# ---------------------------------------------------------------------------

@pytest.mark.timeout_s(600)
@pytest.mark.parametrize("seed", [CHAOS_SEED, CHAOS_SEED + 1])
def test_seeded_chaos_parity(seed):
    rng = np.random.default_rng(seed)
    xyz = _catalog(2000, seed=seed)
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    n_splits = int(rng.integers(2, 7))
    n_lanes = int(rng.integers(1, 5))
    src = FaultySplitSource(ArraySplits(xyz, n_splits=n_splits),
                            seed=seed, delay_p=0.3, fault_p=0.3,
                            delay_s=0.05, max_faults=2)
    res = run_job_streaming(
        job, src, n_lanes=n_lanes, max_retries=2, retry_backoff_s=0.01,
        speculate=SpeculativeConfig(slowdown=2.0, min_finished=2))
    assert res.output == want, (seed, n_splits, n_lanes)


# hypothesis property: random lane counts, boundaries, fault schedules.
# Guarded (not module-level importorskip) so the fixed-case chaos tests above
# always run even where hypothesis is not installed.
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @pytest.mark.timeout_s(900)
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 2**16), n_lanes=st.integers(1, 8),
           n_splits=st.integers(1, 6), spec=st.booleans())
    def test_property_chaos_parity_wordcount(seed, n_lanes, n_splits, spec):
        toks = _tokens(2000, 53)
        job = token_histogram_job(53)
        want = run_job(job, toks).output
        src = FaultySplitSource(ArraySplits(toks, n_splits=n_splits),
                                seed=seed ^ CHAOS_SEED, delay_p=0.25,
                                fault_p=0.25, delay_s=0.03, max_faults=2)
        res = run_job_streaming(
            job, src, n_lanes=n_lanes, max_retries=2, retry_backoff_s=0.005,
            speculate=(SpeculativeConfig(slowdown=2.0, min_finished=2)
                       if spec else None))
        assert np.array_equal(res.output, want), \
            (seed, n_lanes, n_splits, spec)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_chaos_parity_wordcount():
        pass
