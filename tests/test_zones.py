"""Zones MapReduce apps vs brute-force oracles (hypothesis over catalogs)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.data import sky
from repro.mapreduce import (bucket_by_zone, neighbor_search_count,
                             neighbor_statistics)

settings.register_profile("ci", deadline=None, max_examples=10)
settings.load_profile("ci")


@given(n=st.integers(50, 800), radius=st.floats(0.01, 0.3),
       seed=st.integers(0, 100))
def test_neighbor_search_matches_brute_force(n, radius, seed):
    xyz = sky.make_catalog(n, seed)
    got = neighbor_search_count(xyz, radius, tile=64)
    want = sky.brute_force_pairs(xyz, radius)
    assert got == want


@given(seed=st.integers(0, 20))
def test_statistics_matches_brute_force(seed):
    xyz = sky.make_catalog(600, seed)
    edges_rad = np.linspace(0.02, 0.12, 6)
    h = neighbor_statistics(xyz, edges_arcsec=edges_rad / sky.ARCSEC, tile=64)
    hb = sky.brute_force_hist(xyz, np.concatenate([[0], edges_rad]))
    assert np.array_equal(h, hb)


def test_compressed_shuffle_close():
    """int16 coordinate shuffle (LZO analogue): 2x fewer bytes, tiny count error."""
    xyz = sky.make_catalog(2000, 5)
    radius = 0.05
    zd_full = bucket_by_zone(xyz, radius, tile=64)
    zd_comp = bucket_by_zone(xyz, radius, tile=64, compress_coords=True)
    assert zd_comp.shuffle_bytes * 2 == zd_full.shuffle_bytes
    a = neighbor_search_count(xyz, radius, tile=64)
    b = neighbor_search_count(xyz, radius, tile=64, compress_coords=True)
    assert abs(a - b) <= max(3, int(0.01 * a))


def test_border_replication_sound():
    """Bucket arrays must contain every point within radius of the zone."""
    xyz = sky.make_catalog(500, 9)
    radius = 0.1
    zd = bucket_by_zone(xyz, radius, tile=64)
    dec = sky.dec_of(xyz)
    z = np.clip(((dec + np.pi / 2) / zd.zone_height).astype(int), 0,
                zd.owned.shape[0] - 1)
    for k in range(zd.owned.shape[0]):
        # every point whose dec is within radius of band k must be in bucket k
        lo = k * zd.zone_height - np.pi / 2 - radius
        hi = (k + 1) * zd.zone_height - np.pi / 2 + radius
        members = {tuple(np.round(p, 5)) for p in zd.bucket[k]
                   if np.linalg.norm(p) > 0.5}
        need = xyz[(dec >= lo) & (dec <= hi)]
        for p in need:
            assert tuple(np.round(p, 5)) in members
