"""Bucketing (output-buffering analogue) roundtrip properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.core import buckets as bk

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")

_DTYPES = [jnp.float32, jnp.bfloat16]


@given(
    shapes=st.lists(
        st.tuples(st.integers(1, 8), st.integers(1, 8)), min_size=1,
        max_size=10),
    bucket_bytes=st.sampled_from([64, 256, 1 << 20]),
    pad=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 50),
)
def test_flatten_unflatten_roundtrip(shapes, bucket_bytes, pad, seed):
    rng = np.random.default_rng(seed)
    tree = {f"p{i}": jnp.asarray(rng.normal(size=s),
                                 _DTYPES[i % len(_DTYPES)])
            for i, s in enumerate(shapes)}
    plan = bk.make_plan(tree, bucket_bytes, pad)
    assert all(s % pad == 0 for s in plan.bucket_sizes)
    buckets = bk.flatten(plan, tree)
    back = bk.unflatten(plan, buckets)
    for k in tree:
        np.testing.assert_allclose(np.asarray(back[k], np.float32),
                                   np.asarray(tree[k], np.float32),
                                   atol=1e-6)
        assert back[k].dtype == tree[k].dtype


def test_bucket_count_scales_with_limit(rng):
    tree = {f"p{i}": jnp.zeros((1000,), jnp.float32) for i in range(16)}
    small = bk.make_plan(tree, bucket_bytes=4000)
    big = bk.make_plan(tree, bucket_bytes=1 << 20)
    assert len(small.bucket_sizes) == 16      # one tensor per bucket
    assert len(big.bucket_sizes) == 1         # fully fused (the paper's buffering)
