"""Multi-device semantics via subprocess (8 host devices; smoke tests keep 1)."""
import os
import subprocess
import sys

import jax
import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "md_check.py")

# partial-manual shard_map (manual DP axes, auto model axis) needs current
# jax; the 0.4.x fallback is fully manual and trips XLA on the model axis
_OLD_JAX = not hasattr(jax, "shard_map")


def _run(check: str, timeout: int = 900):
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, SCRIPT, check], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"{check} failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_hierarchical_equals_flat_psum():
    assert "OK" in _run("hier")


@pytest.mark.slow
def test_compressed_psum_close_to_exact():
    assert "OK" in _run("compressed")


@pytest.mark.slow
def test_moe_expert_parallel_multidevice():
    assert "OK" in _run("moe")


@pytest.mark.slow
@pytest.mark.skipif(_OLD_JAX, reason="explicit train path needs partial-"
                    "manual shard_map (current jax)")
def test_train_modes_multidevice():
    assert "OK" in _run("train")


@pytest.mark.slow
def test_mapreduce_device_sharded_multidevice():
    """Sharded device engine == host mesh oracle (bit-exact), 8 host devices:
    ragged tier counts, single-shard tiers, empty partitions, both shuffle
    index paths, and the traceable in-shard_map reduce."""
    assert "OK" in _run("mapreduce-device")


@pytest.mark.slow
def test_mapreduce_streaming_sharded_multidevice():
    """Split-streaming executor == monolithic on an 8-device data mesh
    (2/5/n-of-1 splits, identity+int16, wordcount combiner on/off/auto)."""
    assert "OK" in _run("mapreduce-streaming")


@pytest.mark.slow
def test_mapreduce_lanes_multidevice():
    """Per-device concurrent lanes across 8 host devices == monolithic,
    with and without injected chaos (delays, transient faults, clones)."""
    assert "OK" in _run("mapreduce-lanes")
