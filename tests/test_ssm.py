"""Mamba-2 SSD: chunked algorithm vs naive sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked


def naive_ssd(x, dt, A, Bm, Cm):
    """Sequential oracle: h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T; y = C_t h."""
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = np.repeat(np.asarray(Bm, np.float64), rep, axis=2)
    Ch = np.repeat(np.asarray(Cm, np.float64), rep, axis=2)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    y = np.zeros((B, L, H, P))
    h = np.zeros((B, H, N, P))
    for t in range(L):
        dA = np.exp(dtf[:, t] * Af)                     # [B,H]
        xdt = xf[:, t] * dtf[:, t][..., None]           # [B,H,P]
        h = h * dA[..., None, None] + np.einsum("bhn,bhp->bhnp", Bh[:, t], xdt)
        y[:, t] = np.einsum("bhn,bhnp->bhp", Ch[:, t], h)
    return y


@pytest.mark.parametrize("L,chunk,H,G", [(32, 8, 4, 1), (48, 16, 4, 2),
                                         (64, 64, 2, 1)])
def test_ssd_chunked_matches_naive(rng, L, chunk, H, G):
    B, P, N = 2, 8, 8
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    x = jax.random.normal(k1, (B, L, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k2, (B, L, H)) * 0.3)
    A = -jnp.exp(jax.random.normal(k3, (H,)) * 0.3)
    Bm = jax.random.normal(k4, (B, L, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(k4, 1), (B, L, G, N)) * 0.5
    got = np.asarray(ssd_chunked(x, dt, A, Bm, Cm, chunk))
    want = naive_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_decode_matches_prefill(rng, cpu_mesh):
    """One recurrent decode step after a prefill must equal the full-seq result."""
    from repro.configs import get_arch, RunConfig
    from repro.models import model as mdl
    from repro.parallel.sharding import use_mesh
    cfg = get_arch("mamba2-1.3b").reduced()
    rc = RunConfig(remat="none")
    S = 32
    with use_mesh(cpu_mesh):
        params, biases = mdl.init(cfg, rng)
        toks = jax.random.randint(rng, (2, S + 2), 0, cfg.vocab)
        logits_full, _, _, _ = mdl.forward(cfg, rc, params, biases,
                                           {"tokens": toks})
        cache, _ = mdl.prefill(cfg, rc, params, biases,
                               {"tokens": toks[:, :S]}, max_len=S + 8)
        d1, cache = mdl.decode_step(cfg, rc, params, biases, cache,
                                    toks[:, S:S + 1], jnp.int32(S))
        d2, _ = mdl.decode_step(cfg, rc, params, biases, cache,
                                toks[:, S + 1:S + 2], jnp.int32(S + 1))
        for dec, pos in [(d1, S), (d2, S + 1)]:
            ref = logits_full[:, pos].astype(jnp.float32)
            rel = float(jnp.max(jnp.abs(dec.astype(jnp.float32) - ref)) /
                        jnp.maximum(jnp.max(jnp.abs(ref)), 1.0))
            assert rel < 0.06, (pos, rel)
