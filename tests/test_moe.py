"""MoE expert-parallel dispatch correctness vs a dense per-expert loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import moe as moe_mod
from repro.models.common import activate
from repro.parallel.sharding import init_params, use_mesh


def _setup(rng, cfg):
    p = init_params(moe_mod.moe_schema(cfg), rng, dtype_override="float32")
    bias = jnp.zeros((cfg.moe.n_experts_padded,), jnp.float32)
    return p, bias


def dense_oracle(cfg, p, x, bias):
    """Route + run every token through its top-k experts exactly (no capacity)."""
    m = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt @ p["router"]
    gates, ids, _ = moe_mod.route(m, logits, bias)
    y = jnp.zeros_like(xt)
    for e in range(m.n_experts):
        h_up = xt @ p["w_up"][e]
        h_g = xt @ p["w_gate"][e]
        out_e = (activate(cfg.act, h_g) * h_up) @ p["w_down"][e]
        w_e = jnp.sum(jnp.where(ids == e, gates, 0.0), axis=1)
        y = y + out_e * w_e[:, None]
    y = y.reshape(B, S, D)
    if m.n_shared:
        from repro.models.ffn import ffn_apply
        y = y + ffn_apply(cfg, p["shared"], x)
    return y


@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "deepseek-v3-671b"])
def test_moe_matches_dense_oracle(rng, cpu_mesh, arch):
    cfg = get_arch(arch).reduced()
    # generous capacity so nothing drops -> exact match expected
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    with use_mesh(cpu_mesh):
        p, bias = _setup(rng, cfg)
        x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.float32) * 0.5
        y, aux = moe_mod.moe_apply(cfg, p, x, bias)
        y_ref = dense_oracle(cfg, p, x, bias)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    # load adds up to n_tokens * top_k
    assert int(jnp.sum(aux["load"])) == 2 * 16 * cfg.moe.top_k


def test_moe_capacity_drops_tokens(rng, cpu_mesh):
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    with use_mesh(cpu_mesh):
        p, bias = _setup(rng, cfg)
        x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.float32)
        y, _ = moe_mod.moe_apply(cfg, p, x, bias)
        y_ref = dense_oracle(cfg, p, x, bias)
    # with tiny capacity the outputs must differ (tokens dropped)...
    assert not np.allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    # ...but stay finite
    assert np.all(np.isfinite(np.asarray(y)))


def test_moe_grads_flow(rng, cpu_mesh):
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    with use_mesh(cpu_mesh):
        p, bias = _setup(rng, cfg)
        x = jax.random.normal(rng, (1, 16, cfg.d_model), jnp.float32)

        def loss(p):
            y, _ = moe_mod.moe_apply(cfg, p, x, bias)
            return jnp.sum(jnp.square(y))

        g = jax.grad(loss)(p)
    for name in ("w_up", "w_gate", "w_down", "router"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name


def test_router_bias_update_direction():
    cfg = get_arch("deepseek-v3-671b").reduced()
    m = dataclasses.replace(cfg.moe, n_expert_pad=4)   # exercise the pad mask
    bias = jnp.zeros((m.n_experts_padded,), jnp.float32)
    load = jnp.zeros((m.n_experts_padded,)).at[0].set(100.0)  # expert 0 hot
    new = moe_mod.update_router_bias(m, bias, load)
    assert float(new[0]) < 0            # hot expert pushed down
    assert float(new[1]) > 0            # cold real experts pulled up
    assert float(new[m.n_experts]) == 0  # padded experts never touched


def test_route_never_selects_padded():
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    import dataclasses as dc
    m = dc.replace(cfg.moe, n_expert_pad=4)
    logits = jnp.asarray(np.random.default_rng(0).normal(
        size=(64, m.n_experts_padded)), jnp.float32)
    _, ids, _ = moe_mod.route(m, logits, jnp.zeros((m.n_experts_padded,)))
    assert int(jnp.max(ids)) < m.n_experts


def test_compressed_a2a_roundtrip_quality(rng):
    x = jax.random.normal(rng, (4, 32, 64), jnp.float32)
    q, s = moe_mod._q8(x)
    back = moe_mod._dq8(q, s, x.dtype)
    err = float(jnp.max(jnp.abs(back - x)))
    bound = float(jnp.max(jnp.abs(x))) / 127.0 * 0.51
    assert err <= bound
