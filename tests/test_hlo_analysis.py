"""HLO analyzer units: dot FLOPs, loop multipliers, collective classification."""
import numpy as np

from repro.core.hlo_analysis import analyze_hlo, _parse_groups, _shape_bytes

HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %w = f32[8,8] constant({...})
  %d = f32[8,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  %ar = f32[8,8] all-reduce(%a), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%cond
  %ag = f32[16,8] all-gather(%a), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_loop_multiplied_dot_flops():
    a = analyze_hlo(HLO)
    # 7 iterations x 2*8*8*8 flops
    assert a.flops == 7 * 2 * 8 * 8 * 8


def test_collective_wire_bytes():
    a = analyze_hlo(HLO)
    ops = {c.op: c for c in a.collectives}
    # all-reduce: 2 * 256B * 3/4
    assert abs(ops["all-reduce"].wire_bytes - 2 * 256 * 0.75) < 1e-6
    # all-gather: output 512B * 3/4
    assert abs(ops["all-gather"].wire_bytes - 512 * 0.75) < 1e-6


def test_cross_pod_classification():
    a = analyze_hlo(HLO, pod_size=4)
    ops = {c.op: c for c in a.collectives}
    assert not ops["all-reduce"].cross_pod        # {0..3} within pod 0
    assert not ops["all-gather"].cross_pod        # iota [2,4]<=[8]: group0={0..3}
    # transposed iota spreads a group across pods: [4,2]<=[2,4]T(1,0) -> {0,4},...
    line = ("%x = f32[8] all-gather(%a), "
            "replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}")
    a2 = analyze_hlo("ENTRY %m (a: f32[8]) -> f32[8] {\n  " + line +
                     "\n  ROOT %r = f32[8] add(%x, %x)\n}\n", pod_size=4)
    assert a2.collectives and a2.collectives[0].cross_pod


def test_iota_group_parse():
    gsize, cross = _parse_groups(
        "x = f32[4] all-gather(%y), replica_groups=[2,4]<=[8], dimensions={0}",
        pod_size=4)
    assert gsize == 4
    ids = np.arange(8).reshape(2, 4)
    assert cross == (len({int(i) // 4 for i in ids[0]}) > 1)


def test_shape_bytes():
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
    assert _shape_bytes("s8[100]") == 100


# ---------------------------------------------------------------------------
# census over real mapreduce stage callables (the cost model's inputs)
# ---------------------------------------------------------------------------

def test_census_counts_dot_flops_in_reduce_stage():
    import jax
    import jax.numpy as jnp
    from repro.core.cost_model import stage_census

    P, C1, C2, d = 4, 64, 96, 3
    a = jax.ShapeDtypeStruct((P, C1, d), jnp.float32)
    b = jax.ShapeDtypeStruct((P, C2, d), jnp.float32)
    cen = stage_census(lambda x, y: jnp.einsum("pcd,ped->pce", x, y), a, b)
    # one batched dot: 2 * P * C1 * C2 * d FLOPs, reads/writes nonzero bytes
    assert cen.flops == 2.0 * P * C1 * C2 * d
    assert cen.hbm_bytes > 0


def test_census_blocked_chunk_elementwise_flops():
    from repro.core.cost_model import _probe_args, stage_census
    from repro.kernels.zones_pairs.blocked import _count_chunk

    cen = stage_census(_count_chunk, *_probe_args(32, 32, 64))
    # the pair kernel is an unrolled broadcast-multiply-add (the bit-parity
    # contract forbids a real dot), so its work shows up as ELEMENTWISE
    # flops inside fusions — zero dot flops is load-bearing, not a gap
    assert cen.flops == 0.0
    assert cen.ew_flops > 0.0
    assert cen.hbm_bytes > 0.0
    assert cen.summary()["ew_flops_per_device"] == cen.ew_flops
