"""Attention inner-loop equivalence + decode cache semantics."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attend


def _qkv(key, B, S, H, Kv, dh, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, dh), dtype) * 0.5
    k = jax.random.normal(k2, (B, S, Kv, dh), dtype) * 0.5
    v = jax.random.normal(k3, (B, S, Kv, dh), dtype) * 0.5
    return q, k, v


@pytest.mark.parametrize("S,H,Kv,window,cap", [
    (64, 4, 4, 0, 0.0),
    (64, 4, 2, 0, 0.0),          # GQA
    (128, 4, 1, 32, 0.0),        # MQA + window
    (128, 8, 4, 0, 30.0),        # softcap
])
def test_chunked_matches_masked(rng, S, H, Kv, window, cap):
    q, k, v = _qkv(rng, 2, S, H, Kv, 16)
    a = attend(q, k, v, causal=True, window=window, cap=cap, impl="masked")
    b = attend(q, k, v, causal=True, window=window, cap=cap, impl="chunked",
               chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("S,window", [(128, 0), (128, 48), (96, 32)])
def test_blocked_causal_matches_masked(rng, S, window):
    q, k, v = _qkv(rng, 2, S, 4, 2, 16)
    a = attend(q, k, v, causal=True, window=window, impl="masked")
    b = attend(q, k, v, causal=True, window=window, impl="blocked_causal",
               chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_blocked_causal_skips_blocks(rng):
    """The triangular schedule must run ~half the blocks of the full grid."""
    from repro.models.attention import _attend_blocked
    # count scan length via jaxpr
    q, k, v = _qkv(rng, 1, 256, 2, 2, 8)
    qg = q.reshape(1, 256, 2, 1, 8)
    jaxpr = jax.make_jaxpr(
        lambda a, b, c: _attend_blocked(a, b, c, scale=1.0, cap=0.0,
                                        causal=True, window=0, chunk=64))(qg, k, v)
    scan_eqs = [e for e in jaxpr.eqns if e.primitive.name == "scan"]
    assert scan_eqs and scan_eqs[0].params["length"] == 4 * 5 // 2  # nb(nb+1)/2


def test_bf16_paths(rng):
    q, k, v = _qkv(rng, 1, 64, 4, 2, 16, jnp.bfloat16)
    a = attend(q, k, v, causal=True, impl="masked")
    b = attend(q, k, v, causal=True, impl="chunked", chunk=16)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=3e-2)
