"""External shuffle spill tier: segment store units + out-of-core parity.

The tentpole claim is bit parity: spill ON (any budget, 0 and huge included)
must equal spill OFF must equal the monolithic oracle, while peak resident
wire bytes stay within budget + one spill chunk. The unit half exercises the
``SpillStore`` contract directly — range-bucketed staging, finalize-rename
crash safety, truncation refusal, segment reclamation on success AND on
injected write failure — and the e2e half runs real pair jobs through
``run_job_streaming(spill=...)`` under tmp spill dirs.
"""
import os

import numpy as np
import pytest

from repro.data import ArraySplits, SpilledStreamSplits, sky
from repro.mapreduce import (MappedSplit, SpillConfig, SpillStore,
                             mapped_wire_nbytes, neighbor_search_job,
                             plan_bounds, run_job, run_job_streaming)
from repro.mapreduce.spill import _read_segment

RADIUS = 0.02


def _catalog(n=2500, seed=0):
    return sky.make_catalog(n, seed=seed)


def _mapped(seed=0, n_rows=40, P=12, d=2):
    """A hand-built host MappedSplit: random keys, every row also emitted as
    a bucket entry to a (possibly different) partition — so ranges see both
    owned rows and payload-only border rows."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, P, n_rows).astype(np.int32)
    dest = rng.integers(0, P, n_rows).astype(np.int32)
    src = rng.permutation(n_rows).astype(np.int32)
    pay = rng.integers(-99, 99, (n_rows, d)).astype(np.int16)
    return MappedSplit(payloads=(pay,), keys=keys, dest_eff=dest, src=src,
                       skey=None, n_rows=n_rows, d=d, nbytes_in=0)


def _entry_sums(P, recs):
    """Oracle: per-partition sum over bucket entries of the referenced
    payload rows — the quantity any dest/src remap must preserve."""
    out = np.zeros((P, recs[0].payloads[0].shape[1]), np.int64)
    for m in recs:
        np.add.at(out, np.asarray(m.dest_eff),
                  np.asarray(m.payloads[0])[np.asarray(m.src)].astype(np.int64))
    return out


# ---------------------------------------------------------------------------
# SpillStore units
# ---------------------------------------------------------------------------

def test_plan_bounds_properties():
    b = plan_bounds(np.ones(16), 4)
    assert b.tolist() == [0, 4, 8, 12, 16]
    # skewed weight -> byte-balanced, still strictly increasing [0..P]
    w = np.zeros(10)
    w[0] = 100.0
    b = plan_bounds(w, 4)
    assert b[0] == 0 and b[-1] == 10 and (np.diff(b) > 0).all()
    # more ranges than partitions clamps
    assert plan_bounds(np.ones(3), 99).tolist() == [0, 1, 2, 3]


@pytest.mark.timeout_s(120)
def test_spill_store_roundtrip_multi_chunk(tmp_path):
    """stage+commit two chunks, read every range back: merged entry streams
    (src offsets across chunks/segments) preserve the per-partition sums,
    owned-row keys are range-local, and border rows carry the span
    sentinel."""
    P = 12
    recs = [_mapped(seed=1), _mapped(seed=2, n_rows=23)]
    store = SpillStore(str(tmp_path / "sp"), P)
    store.set_bounds(plan_bounds(np.ones(P), 3))
    try:
        for m in recs:
            store.commit_chunk(store.stage_chunk([m], store.next_tag()))
        assert store.n_chunks == 2
        want = _entry_sums(P, recs)
        got = np.zeros_like(want)
        rows_seen = owned_seen = 0
        for z in range(store.n_ranges):
            r = store.read_range(z)
            lo, hi, span = r["lo"], r["hi"], r["hi"] - r["lo"]
            assert r["keys"].min() >= 0 and r["keys"].max() <= span
            assert (0 <= r["dest_eff"]).all() and (r["dest_eff"] < span).all()
            assert (0 <= r["src"]).all() and (r["src"] < r["n_rows"]).all()
            np.add.at(got, r["dest_eff"] + lo,
                      r["payloads"][0][r["src"]].astype(np.int64))
            rows_seen += r["n_rows"]
            owned_seen += int((r["keys"] < span).sum())
        assert np.array_equal(got, want)
        # every owned row lands in exactly one range
        assert owned_seen == sum(len(m.keys) for m in recs)
    finally:
        store.close()
    assert not (tmp_path / "sp").exists()      # reclaimed on close


@pytest.mark.timeout_s(120)
def test_staged_chunks_invisible_until_commit_and_swept(tmp_path):
    """Finalize-rename: a staged-but-uncommitted chunk never contributes to
    read_range; sweep_staged reclaims its litter (the cancelled-clone /
    killed-writer path)."""
    P = 8
    m = _mapped(seed=3, P=P)
    store = SpillStore(str(tmp_path / "sp"), P)
    store.set_bounds([0, P])
    try:
        store.commit_chunk(store.stage_chunk([m], store.next_tag()))
        before = store.read_range(0)
        loser = store.stage_chunk([m], store.next_tag())   # never committed
        assert any(".staged-" in p for _, p in loser.paths)
        after = store.read_range(0)
        assert np.array_equal(before["payloads"][0], after["payloads"][0])
        assert after["n_rows"] == m.n_rows                 # not doubled
        assert store.sweep_staged() == 1
        assert all(".staged-" not in f for f in os.listdir(store.root))
    finally:
        store.close()


@pytest.mark.timeout_s(120)
def test_truncated_segment_refused_with_path_and_remainder(tmp_path):
    """The MemmapCatalogSplits refusal, applied to spill segments: a
    crash-truncated file raises ValueError naming the path and the byte
    remainder instead of silently reading a shorter stream."""
    P = 6
    store = SpillStore(str(tmp_path / "sp"), P)
    store.set_bounds([0, P])
    try:
        store.commit_chunk(store.stage_chunk([_mapped(seed=4, P=P)],
                                             store.next_tag()))
        path = store.range_segment_paths(0)[0]
        data = open(path, "rb").read()
        with open(path, "wb") as f:
            f.write(data[:-3])                 # torn write: 3 bytes short
        with pytest.raises(ValueError, match=r"-3 byte remainder") as ei:
            store.read_range(0)
        assert path in str(ei.value)
        # garbage magic is refused too
        with open(path, "wb") as f:
            f.write(b"JUNKJUNK")
        with pytest.raises(ValueError, match="magic"):
            store.read_range(0)
    finally:
        store.close()


@pytest.mark.timeout_s(120)
def test_injected_write_fault_leaves_invalid_staged_file(tmp_path):
    """A writer killed mid-segment-write leaves a length-invalid staged file
    (payload+keys written, index fields missing) that read-side validation
    refuses — and the failed chunk is reclaimable by sweep."""
    P = 6
    seen = {}

    def die(path):
        seen["path"] = path
        raise OSError("lane died mid-spill-write")

    store = SpillStore(str(tmp_path / "sp"), P, write_fault=die)
    store.set_bounds([0, P])
    try:
        with pytest.raises(OSError, match="mid-spill-write"):
            store.stage_chunk([_mapped(seed=5, P=P)], store.next_tag())
        assert ".staged-" in seen["path"] and os.path.exists(seen["path"])
        with pytest.raises(ValueError, match="remainder"):
            _read_segment(seen["path"])
        assert store.n_chunks == 0             # nothing committed
        assert store.sweep_staged() >= 1       # litter reclaimed
    finally:
        store.close()


def test_spilled_stream_splits_wraps_store(tmp_path):
    P = 6
    store = SpillStore(str(tmp_path / "sp"), P)
    store.set_bounds([0, 3, P])
    try:
        store.commit_chunk(store.stage_chunk([_mapped(seed=6, P=P)],
                                             store.next_tag()))
        src = SpilledStreamSplits(store)
        assert src.n_splits() == store.n_ranges == 2
        rec = src.split(1)
        assert (rec["lo"], rec["hi"]) == (3, 6)
        with pytest.raises(TypeError):
            src.materialize()                  # defeats out-of-core: refused
    finally:
        store.close()


# ---------------------------------------------------------------------------
# e2e: out-of-core pair jobs, bit parity and peak-residency bound
# ---------------------------------------------------------------------------

@pytest.mark.timeout_s(600)
def test_spill_parity_over_budgets(tmp_path):
    """The acceptance property: spill(budget) == spill-off == monolithic for
    budget = 0 (spill everything), small (real out-of-core), huge (never
    trips), None (disabled); peak resident wire bytes <= budget + one chunk;
    spill dirs always reclaimed."""
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    off = run_job_streaming(job, ArraySplits(xyz, n_splits=6))
    assert off.output == want
    for budget in (0, 20_000, 10**12, None):
        root = tmp_path / f"sp{budget}"
        cfg = SpillConfig(budget_bytes=budget, dir=str(root))
        res = run_job_streaming(job, ArraySplits(xyz, n_splits=6), spill=cfg)
        st = res.stats
        assert res.output == want, f"budget={budget}"
        assert not root.exists(), f"budget={budget}: spill dir leaked"
        if budget in (None, 10**12):           # never tripped: today's path
            assert st.spilled_splits == 0 and st.spill_bytes == 0
        else:
            assert st.spilled_splits == 6
            assert st.spill_bytes > 0 and st.spill_ranges >= 1
            assert st.spill_peak_bytes <= budget + st.spill_chunk_bytes
            assert st.spill_wall_s > 0 and st.wall_s >= st.spill_wall_s


@pytest.mark.timeout_s(300)
def test_spill_lane_mode_parity(tmp_path):
    """Lane mode spills at map time (each split stages its own chunk, commit
    under the pool lock) — concurrent lanes, same bits, dir reclaimed."""
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    root = tmp_path / "sp"
    res = run_job_streaming(
        job, ArraySplits(xyz, n_splits=6), n_lanes=3,
        spill=SpillConfig(budget_bytes=10_000, dir=str(root)))
    assert res.output == want
    assert res.stats.spilled_splits == 6
    assert res.stats.spill_ranges >= 1
    assert not root.exists()


@pytest.mark.timeout_s(300)
def test_spill_write_failure_reclaims_segments(tmp_path):
    """Sequential path, spill write dies: the error surfaces (not swallowed
    by the async writer) and the spill dir is reclaimed by the executor's
    try/finally — no orphaned segments."""
    xyz = _catalog(1200)
    job = neighbor_search_job(RADIUS, tile=128)

    def die(path):
        raise OSError("spill disk died")

    root = tmp_path / "sp"
    cfg = SpillConfig(budget_bytes=0, dir=str(root), write_fault=die)
    with pytest.raises(OSError, match="spill disk died"):
        run_job_streaming(job, ArraySplits(xyz, n_splits=4), spill=cfg)
    assert not root.exists()


@pytest.mark.timeout_s(120)
def test_spill_requires_device_engine_and_ignores_combine():
    xyz = _catalog(400)
    job = neighbor_search_job(RADIUS, tile=128)
    with pytest.raises(ValueError, match="device engine"):
        run_job_streaming(job, ArraySplits(xyz, 2), engine="host",
                          spill=0)
    # wordcount (combine mode): nothing accumulates, spill is a no-op
    from repro.mapreduce import token_histogram_job
    toks = (np.arange(1500) % 53).astype(np.float32).reshape(-1, 1)
    wjob = token_histogram_job(53)
    want = run_job(wjob, toks).output
    res = run_job_streaming(wjob, ArraySplits(toks, 3), spill=0)
    assert np.array_equal(res.output, want)
    assert res.stats.spilled_splits == 0


def test_mapped_wire_nbytes_counts_all_fields():
    m = _mapped(seed=7)
    n = mapped_wire_nbytes(m)
    assert n == (m.payloads[0].nbytes + m.keys.nbytes + m.dest_eff.nbytes
                 + m.src.nbytes)


# hypothesis property: random budgets AND random split boundaries — the
# spill cut points and the split cut points are both adversarial inputs.
# Guarded so the fixed-case tests above run where hypothesis is missing.
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @pytest.mark.timeout_s(900)
    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 2**16), n_cuts=st.integers(0, 5),
           budget_kb=st.integers(0, 64))
    def test_property_spill_parity(seed, n_cuts, budget_kb):
        rng = np.random.default_rng(seed)
        xyz = _catalog(800, seed=seed % 7)
        job = neighbor_search_job(RADIUS, tile=128)
        want = run_job(job, xyz).output
        bounds = sorted(int(b) for b in
                        rng.integers(0, len(xyz), n_cuts))  # dups/empties ok
        src = ArraySplits(xyz, boundaries=bounds)
        res = run_job_streaming(job, src, spill=float(budget_kb) * 1024)
        assert res.output == want, (seed, bounds, budget_kb)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_spill_parity():
        pass
