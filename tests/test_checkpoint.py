"""Checkpointing: roundtrip, integrity, replication, failure fallback, async."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, chunk_checksums, verify


def _state(rng):
    k1, k2 = jax.random.split(rng)
    return {"params": {"w": jax.random.normal(k1, (16, 8)),
                       "b": jax.random.normal(k2, (8,))},
            "opt": {"m": [jnp.zeros((4,)), jnp.ones((4,))]},
            "step": jnp.int32(7)}


def _trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def test_roundtrip(tmp_path, rng):
    st = _state(rng)
    ck = Checkpointer(str(tmp_path), replication=2, async_io=False)
    ck.save(10, st, mesh_shape=(1, 1))
    back, manifest = ck.restore(st)
    assert manifest["step"] == 10
    assert _trees_equal(st, back)


def test_async_save_then_restore(tmp_path, rng):
    st = _state(rng)
    ck = Checkpointer(str(tmp_path), replication=2, async_io=True)
    ck.save(3, st)
    ck.wait()
    back, _ = ck.restore(st)
    assert _trees_equal(st, back)


def test_replica_fallback_on_corruption(tmp_path, rng):
    st = _state(rng)
    ck = Checkpointer(str(tmp_path), replication=2, async_io=False)
    ck.save(1, st)
    # corrupt the primary replica of one leaf
    d = ck.step_dir(1)
    import json
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    key, meta = next(iter(manifest["leaves"].items()))
    victim = os.path.join(d, f"host_{meta['hosts'][0]}", meta["file"])
    arr = np.load(victim)
    arr2 = np.array(arr)
    arr2.reshape(-1)[0] += 1.0
    np.save(victim, arr2)
    back, _ = ck.restore(st)
    assert _trees_equal(st, back)       # restored from the surviving replica


def test_failed_hosts_simulation(tmp_path, rng):
    st = _state(rng)
    ck = Checkpointer(str(tmp_path), replication=2, n_hosts=4, async_io=False)
    ck.save(1, st)
    back, _ = ck.restore(st, failed_hosts={0})
    assert _trees_equal(st, back)
    with pytest.raises(IOError):
        ck.restore(st, failed_hosts={0, 1, 2, 3})


def test_gc_keeps_latest(tmp_path, rng):
    st = _state(rng)
    ck = Checkpointer(str(tmp_path), replication=1, async_io=False, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, st)
    assert ck.list_steps() == [3, 4]


def test_checksum_chunk_api():
    buf = np.arange(10000, dtype=np.float32)
    sums = chunk_checksums(buf, chunk=1024)
    assert verify(buf, sums, chunk=1024) == -1
    bad = np.array(buf)
    bad[2000] = -1
    idx = verify(bad, sums, chunk=1024)
    assert idx == (2000 * 4) // 1024


def test_elastic_restore_new_sharding(tmp_path, rng, cpu_mesh):
    """Checkpoint saved without shardings restores onto explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    st = _state(rng)
    ck = Checkpointer(str(tmp_path), replication=1, async_io=False)
    ck.save(1, st)
    sh = jax.tree.map(lambda _: NamedSharding(cpu_mesh, P()), st)
    back, _ = ck.restore(st, shardings=sh)
    assert _trees_equal(st, back)
