"""Per-arch smoke: reduced config forward/train-step/decode on CPU (1 device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, get_arch
from repro.models import model as mdl
from repro.parallel.sharding import use_mesh
from repro.training.state import init_state
from repro.training.step import make_train_step

S = 32
B = 2


def _batch(cfg, key, seq=S, batch=B):
    out = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab)}
    if cfg.cross_attn:
        out["cond"] = jax.random.normal(key, (batch, cfg.cond_len, cfg.d_model),
                                        jnp.bfloat16)
    if cfg.prefix_embeds:
        out["prefix"] = jax.random.normal(
            key, (batch, cfg.prefix_embeds, cfg.d_model), jnp.bfloat16)
    return out


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_and_finite(name, cpu_mesh, rng):
    cfg = get_arch(name).reduced()
    rc = RunConfig(remat="none")
    with use_mesh(cpu_mesh):
        params, biases = mdl.init(cfg, rng)
        batch = _batch(cfg, rng)
        logits, _, _, _ = mdl.forward(cfg, rc, params, biases, batch)
        assert logits.shape == (B, S, cfg.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        loss, (mets, _) = mdl.loss_fn(cfg, rc, params, biases, batch)
        assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_runs(name, cpu_mesh, rng):
    cfg = get_arch(name).reduced()
    rc = RunConfig(remat="none", bucketed_updates=cfg.optimizer != "adafactor")
    step_fn, _, _, rules = make_train_step(cfg, rc, cpu_mesh)
    with use_mesh(cpu_mesh, rules):
        state = init_state(cfg, rc, rng, cpu_mesh)
    batch = _batch(cfg, rng)
    state, mets = step_fn(state, batch)
    assert np.isfinite(float(mets["loss"]))
    assert np.isfinite(float(mets["grad_norm"]))
    assert int(state["step"]) == 1
    # params actually changed
    l0 = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.any(l0 != 0))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_consistency(name, cpu_mesh, rng):
    cfg = get_arch(name).reduced()
    rc = RunConfig(remat="none")
    with use_mesh(cpu_mesh):
        params, biases = mdl.init(cfg, rng)
        toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)
        full = _batch(cfg, rng)
        full["tokens"] = toks
        pre = dict(full)
        pre["tokens"] = toks[:, :S]
        logits_full, _, _, _ = mdl.forward(cfg, rc, params, biases, full)
        cache, _ = mdl.prefill(cfg, rc, params, biases, pre, max_len=S + 8)
        dec, _ = mdl.decode_step(cfg, rc, params, biases, cache,
                                 toks[:, S:S + 1], jnp.int32(S))
        ref = logits_full[:, S].astype(jnp.float32)
        got = dec.astype(jnp.float32)
        denom = jnp.maximum(jnp.max(jnp.abs(ref)), 1.0)
        rel = float(jnp.max(jnp.abs(got - ref)) / denom)
        assert rel < 0.07, rel       # bf16 paths reorder reductions (jax/XLA
        # versions differ slightly; deepseek MoE hits 0.0625 on jax 0.4.x CPU)
