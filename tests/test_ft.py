"""Fault-tolerance policy units: stragglers + coordinator."""
from repro.ft import Coordinator, CoordinatorConfig, State, StragglerConfig, \
    StragglerMonitor


def test_no_straggler_on_uniform_times():
    mon = StragglerMonitor([0, 1, 2, 3])
    for _ in range(10):
        for h in range(4):
            mon.record(h, 1.0)
    assert mon.propose()["action"] == "none"


def test_straggler_rebalance_then_exclude():
    cfg = StragglerConfig(patience=2, exclude_after=6)
    mon = StragglerMonitor([0, 1, 2, 3], cfg)
    actions = []
    for _ in range(12):
        for h in range(4):
            mon.record(h, 3.0 if h == 2 else 1.0)
        actions.append(mon.propose()["action"])
    assert "rebalance" in actions
    assert actions[-1] == "exclude"
    prop = mon.propose()
    if prop["action"] == "exclude":
        assert prop["host"] == 2 and 2 not in prop["surviving"]


def test_rebalance_shifts_quota():
    mon = StragglerMonitor([0, 1], StragglerConfig(patience=1))
    for _ in range(3):
        mon.record(0, 1.0)
        mon.record(1, 4.0)
    p = mon.propose()
    assert p["action"] == "rebalance"
    assert p["quota"][1] < 1.0 and p["quota"][0] > 1.0


def test_coordinator_degrade_then_remesh():
    cfg = CoordinatorConfig(heartbeat_timeout=10, misses_to_degrade=2,
                            misses_to_dead=4)
    c = Coordinator([0, 1, 2], cfg)
    now = 0.0
    for h in (0, 1, 2):
        c.heartbeat(h, now)
    assert c.tick(5.0)["action"] == "none"
    # host 2 goes silent
    acts = []
    for t in (20.0, 40.0, 60.0, 80.0):
        c.heartbeat(0, t)
        c.heartbeat(1, t)
        acts.append(c.tick(t)["action"])
    assert "checkpoint_now" in acts
    assert acts[-1] == "remesh"
    assert c.state == State.REMESH
    c.remesh_done()
    assert c.state == State.HEALTHY and c.hosts == {0, 1}


def test_coordinator_aborts_below_min_hosts():
    cfg = CoordinatorConfig(heartbeat_timeout=1, misses_to_degrade=1,
                            misses_to_dead=1, min_hosts=2)
    c = Coordinator([0, 1], cfg)
    act = c.tick(100.0)
    assert act["action"] == "abort"
