"""Fault-tolerance policy units: stragglers, speculative execution,
coordinator."""
from repro.ft import Coordinator, CoordinatorConfig, SpeculativeConfig, \
    SpeculativePolicy, State, StragglerConfig, StragglerMonitor


def test_no_straggler_on_uniform_times():
    mon = StragglerMonitor([0, 1, 2, 3])
    for _ in range(10):
        for h in range(4):
            mon.record(h, 1.0)
    assert mon.propose()["action"] == "none"


def test_straggler_rebalance_then_exclude():
    cfg = StragglerConfig(patience=2, exclude_after=6)
    mon = StragglerMonitor([0, 1, 2, 3], cfg)
    actions = []
    for _ in range(12):
        for h in range(4):
            mon.record(h, 3.0 if h == 2 else 1.0)
        actions.append(mon.propose()["action"])
    assert "rebalance" in actions
    assert actions[-1] == "exclude"
    prop = mon.propose()
    if prop["action"] == "exclude":
        assert prop["host"] == 2 and 2 not in prop["surviving"]


def test_rebalance_shifts_quota():
    mon = StragglerMonitor([0, 1], StragglerConfig(patience=1))
    for _ in range(3):
        mon.record(0, 1.0)
        mon.record(1, 4.0)
    p = mon.propose()
    assert p["action"] == "rebalance"
    assert p["quota"][1] < 1.0 and p["quota"][0] > 1.0


def test_speculative_redispatches_slowest_split():
    """Hadoop's speculative execution: after enough splits complete, a
    running split well past the median completed wall is re-dispatched —
    the SLOWEST one first — and each split is cloned at most max_clones."""
    pol = SpeculativePolicy(SpeculativeConfig(slowdown=1.5, min_finished=3))
    for k in range(3):
        pol.finished(k, 1.0)
    assert pol.propose()["action"] == "none"    # nothing running
    pol.running(7, 1.2)                         # within 1.5x median: fine
    assert pol.propose()["action"] == "none"
    pol.running(8, 4.0)
    pol.running(9, 2.0)
    p = pol.propose()
    assert p == {"action": "speculate", "split": 8, "elapsed_s": 4.0,
                 "expected_s": 1.0}
    p2 = pol.propose()                          # 8 already cloned -> next
    assert p2["action"] == "speculate" and p2["split"] == 9
    assert pol.propose()["action"] == "none"    # everyone cloned or fast
    pol.finished(8, 4.3)                        # original finishes anyway
    pol.running(10, 9.0)
    assert pol.propose()["split"] == 10


def test_speculative_needs_min_finished_and_feeds_like_monitor():
    pol = SpeculativePolicy(SpeculativeConfig(min_finished=3))
    pol.running(5, 100.0)
    pol.record(0, 1.0)                          # executor-hook alias
    pol.record(1, 1.0)
    assert pol.propose()["action"] == "none"    # only 2 finished
    pol.record(2, 1.0)
    assert pol.propose()["action"] == "speculate"


def test_speculative_from_streaming_run():
    """End to end: per-split walls from a real streaming run feed the
    policy; a synthetic stuck split is then the re-dispatch candidate."""
    import numpy as np
    from repro.data import ArraySplits, sky
    from repro.mapreduce import neighbor_search_job, run_job_streaming
    pol = SpeculativePolicy(SpeculativeConfig(min_finished=4))
    res = run_job_streaming(neighbor_search_job(0.08, tile=64),
                            ArraySplits(sky.make_catalog(600, 0), 4),
                            straggler_monitor=pol)
    assert len(pol.walls) == 4
    med = float(np.median(pol.walls))
    pol.running(4, 10_000 * max(med, 1e-9))
    p = pol.propose()
    assert p["action"] == "speculate" and p["split"] == 4


def test_coordinator_degrade_then_remesh():
    cfg = CoordinatorConfig(heartbeat_timeout=10, misses_to_degrade=2,
                            misses_to_dead=4)
    c = Coordinator([0, 1, 2], cfg)
    now = 0.0
    for h in (0, 1, 2):
        c.heartbeat(h, now)
    assert c.tick(5.0)["action"] == "none"
    # host 2 goes silent
    acts = []
    for t in (20.0, 40.0, 60.0, 80.0):
        c.heartbeat(0, t)
        c.heartbeat(1, t)
        acts.append(c.tick(t)["action"])
    assert "checkpoint_now" in acts
    assert acts[-1] == "remesh"
    assert c.state == State.REMESH
    c.remesh_done()
    assert c.state == State.HEALTHY and c.hosts == {0, 1}


def test_coordinator_aborts_below_min_hosts():
    cfg = CoordinatorConfig(heartbeat_timeout=1, misses_to_degrade=1,
                            misses_to_dead=1, min_hosts=2)
    c = Coordinator([0, 1], cfg)
    act = c.tick(100.0)
    assert act["action"] == "abort"


def test_speculative_from_service_batches():
    """Serving mode: the MR query service feeds per-micro-batch walls into
    the policy through the same straggler_monitor= contract the streaming
    executor uses, so a stuck batch is the re-dispatch candidate."""
    import numpy as np
    from repro.data import sky
    from repro.mapreduce import ZonePartitioner, neighbor_search_job
    from repro.serving import MRQueryService
    pol = SpeculativePolicy(SpeculativeConfig(min_finished=3))
    part = ZonePartitioner(0.1)
    svc = MRQueryService(max_batch=1, straggler_monitor=pol)
    svc.load_catalog("sky", sky.make_catalog(300, 0), part, tile=64)
    for _ in range(4):
        svc.submit(neighbor_search_job(0.1, partitioner=part, tile=64),
                   catalog="sky")
    svc.run_pending()                   # max_batch=1 -> 4 micro-batches
    assert len(pol.walls) == 4
    assert pol.walls == [b["wall_s"] for b in svc.batches]
    med = float(np.median(pol.walls))
    pol.running(4, 10_000 * max(med, 1e-9))   # a batch stuck way past median
    p = pol.propose()
    assert p["action"] == "speculate" and p["split"] == 4
    svc.close()
