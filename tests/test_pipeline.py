"""Data pipeline determinism + sharding + memmap backend + split sources."""
import numpy as np
import pytest

from repro.data import (ArraySplits, MemmapCatalogSplits, MemmapTokens,
                        Pipeline, PipelineConfig, Prefetcher,
                        SyntheticCatalogSplits, SyntheticTokens,
                        TokenBlockSplits)


def test_synthetic_deterministic():
    a = SyntheticTokens(1000, seed=7).block(100, 4, 16)
    b = SyntheticTokens(1000, seed=7).block(100, 4, 16)
    assert np.array_equal(a, b)
    c = SyntheticTokens(1000, seed=8).block(100, 4, 16)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


def test_host_shards_are_disjoint_and_cover():
    src = SyntheticTokens(50000, seed=0)
    full = Pipeline(src, PipelineConfig(8, 16, host_id=0, n_hosts=1))
    parts = [Pipeline(src, PipelineConfig(8, 16, host_id=h, n_hosts=2))
             for h in range(2)]
    want = full.batch_at(5)
    got = np.concatenate([p.batch_at(5) for p in parts], axis=0)
    assert np.array_equal(want, got)


def test_elastic_replay_same_batches():
    """A rescaled job (different host count) sees the same global batch."""
    src = SyntheticTokens(1234, seed=1)
    g1 = Pipeline(src, PipelineConfig(12, 8, n_hosts=1)).batch_at(3)
    g2 = np.concatenate([
        Pipeline(src, PipelineConfig(12, 8, host_id=h, n_hosts=3)).batch_at(3)
        for h in range(3)], axis=0)
    assert np.array_equal(g1, g2)


def test_prefetch_iterator():
    """Context manager: the prefetch thread can never leak past the block."""
    with Pipeline(SyntheticTokens(100, 0),
                  PipelineConfig(4, 8, prefetch=2)) as pipe:
        it = iter(pipe)
        s0, b0 = next(it)
        s1, b1 = next(it)
        assert s0 == 0 and s1 == 1
        assert b0.shape == (4, 8) and not np.array_equal(b0, b1)
        assert np.array_equal(b0, pipe.batch_at(0))
    assert pipe._pf is None                     # stopped on exit


def test_memmap_roundtrip(tmp_path):
    path = str(tmp_path / "tokens.bin")
    data = np.arange(4 * 32, dtype=np.int32).reshape(4, 32)
    MemmapTokens.write(path, data)
    src = MemmapTokens(path, seq_len=32)
    assert np.array_equal(src.block(1, 2, 32), data[1:3])
    # wraps around
    assert np.array_equal(src.block(3, 2, 32)[1], data[0])


def test_memmap_block_matches_per_row_oracle(tmp_path):
    """The sliced (vectorized) block read == the old per-row copy loop for
    any (row0, rows), including multi-wrap reads longer than the file."""
    path = str(tmp_path / "tok.bin")
    data = np.random.default_rng(0).integers(0, 999, (5, 16)).astype(np.int32)
    MemmapTokens.write(path, data)
    src = MemmapTokens(path, seq_len=16)
    for row0, rows in [(0, 5), (3, 4), (4, 1), (2, 13), (7, 11), (0, 0)]:
        idx = np.arange(row0, row0 + rows) % src.n_rows
        want = np.stack([data[r] for r in idx], axis=0) if rows else \
            np.zeros((0, 16), np.int32)
        assert np.array_equal(src.block(row0, rows, 16), want), (row0, rows)


def test_prefetcher_finite_and_reports_timing():
    seen = []
    with Prefetcher(lambda k: k * k, depth=2, n=4) as pf:
        while (rec := pf.get()) is not None:
            k, item, wait_s, prep_s = rec
            assert item == k * k and wait_s >= 0 and prep_s >= 0
            seen.append(k)
    assert seen == [0, 1, 2, 3]


def test_prefetcher_propagates_worker_errors():
    def boom(k):
        if k == 1:
            raise RuntimeError("split fetch failed")
        return k
    with Prefetcher(boom, n=3) as pf:
        assert pf.get()[1] == 0
        with pytest.raises(RuntimeError, match="split fetch failed"):
            while pf.get() is not None:
                pass


def test_array_splits_boundaries_and_materialize():
    x = np.arange(20, dtype=np.float32).reshape(10, 2)
    one = ArraySplits(x)
    assert one.n_splits() == 1 and np.array_equal(one.split(0), x)
    cut = ArraySplits(x, boundaries=[0, 3, 3, 10])   # 0/n endpoints + dup 3
    assert cut.n_splits() == 5                       # -> empty edge/middle
    assert [len(cut.split(k)) for k in range(5)] == [0, 3, 0, 7, 0]
    assert np.array_equal(cut.materialize(), x)
    even = ArraySplits(x, n_splits=3)
    assert even.n_splits() == 3
    assert np.array_equal(even.materialize(), x)
    ones = ArraySplits(x, n_splits=100)              # clamps to n rows
    assert ones.n_splits() == 10


def test_memmap_catalog_splits(tmp_path):
    rows = np.random.default_rng(1).normal(size=(17, 3)).astype(np.float32)
    path = str(tmp_path / "cat.f32")
    MemmapCatalogSplits.write(path, rows)
    src = MemmapCatalogSplits(path, d=3, rows_per_split=5)
    assert src.n_splits() == 4
    assert [len(src.split(k)) for k in range(4)] == [5, 5, 5, 2]
    assert np.array_equal(src.materialize(), rows)
    # empty catalog file (mmap rejects empty files): one empty split
    empty = str(tmp_path / "empty.f32")
    MemmapCatalogSplits.write(empty, np.zeros((0, 3), np.float32))
    esrc = MemmapCatalogSplits(empty, d=3, rows_per_split=5)
    assert esrc.n_splits() == 1 and esrc.split(0).shape == (0, 3)


def test_synthetic_catalog_splits_deterministic():
    a = SyntheticCatalogSplits(1000, 256, seed=3)
    b = SyntheticCatalogSplits(1000, 256, seed=3)
    assert a.n_splits() == 4
    assert [len(a.split(k)) for k in range(4)] == [256, 256, 256, 232]
    for k in range(4):
        assert np.array_equal(a.split(k), b.split(k))
    assert not np.array_equal(a.split(0), a.split(1))
    # unit vectors
    np.testing.assert_allclose(np.linalg.norm(a.split(0), axis=1), 1.0,
                               rtol=1e-5)


def test_token_block_splits_match_source():
    src = SyntheticTokens(500, seed=2)
    ts = TokenBlockSplits(src, seq_len=16, rows_per_split=4, n_splits=3)
    assert ts.n_splits() == 3
    for k in range(3):
        want = src.block(k * 4, 4, 16).reshape(-1, 1).astype(np.float32)
        assert np.array_equal(ts.split(k), want)
        assert ts.split(k).shape == (64, 1)


def test_prefetcher_stuck_fetch_raises_named_error():
    """Satellite regression: a worker wedged inside produce(k) used to leak
    silently past stop(); it must now raise, naming the stuck fetch."""
    import threading
    import time

    release = threading.Event()

    def produce(k):
        if k == 1:
            release.wait(20.0)          # wedged fetch (bounded for teardown)
        return np.full((4,), k, np.float32)

    pf = Prefetcher(produce, depth=1, n=5).start()
    k0, item0, _, _ = pf.get()
    assert k0 == 0 and item0[0] == 0
    time.sleep(0.05)                    # let the worker enter produce(1)
    with pytest.raises(RuntimeError, match=r"inside produce\(1\)"):
        pf.stop(timeout=0.2)
    release.set()                       # unwedge so the daemon thread exits
    pf._thread.join(timeout=5.0)
    assert not pf._thread.is_alive()


def test_prefetcher_clean_stop_clears_thread():
    pf = Prefetcher(lambda k: k, depth=2, n=3)
    with pf as p:
        assert p.get()[1] == 0
    assert pf._thread is None           # joined and cleared, no leak


def test_prefetcher_exhaustion_latches():
    """PR8 satellite: get() after the terminal None used to block forever on
    the empty queue (dead worker); the terminal state must latch and
    re-surface on every subsequent call."""
    with Prefetcher(lambda k: k, depth=2, n=2) as pf:
        assert pf.get()[1] == 0
        assert pf.get()[1] == 1
        for _ in range(3):              # every call after the end: None again
            assert pf.get() is None


def test_prefetcher_error_latches():
    """Same latch for producer exceptions: each get() after the first raise
    re-raises the same error instead of hanging."""
    def boom(k):
        raise ValueError("segment write failed")
    with Prefetcher(boom, n=3) as pf:
        for _ in range(3):
            with pytest.raises(ValueError, match="segment write failed"):
                pf.get()


def test_prefetcher_stop_wakes_blocked_consumer():
    """A consumer blocked in get() on an empty queue must wake with None when
    stop() is called from another thread, not sleep forever."""
    import threading
    import time

    release = threading.Event()

    def produce(k):
        release.wait(20.0)              # nothing ever arrives until teardown
        return k

    pf = Prefetcher(produce, depth=1, n=2).start()
    out = {}

    def consume():
        out["rec"] = pf.get()

    t = threading.Thread(target=consume, daemon=True)
    t.start()
    time.sleep(0.1)                     # consumer is now blocked in get()
    assert t.is_alive()
    with pytest.raises(RuntimeError):   # worker is wedged -> named error
        pf.stop(timeout=0.3)
    t.join(timeout=5.0)
    assert not t.is_alive()             # ...but the consumer DID wake
    assert out["rec"] is None
    release.set()
    pf._thread.join(timeout=5.0)


def test_prefetcher_drain_keeps_inflight_item():
    """PR8 satellite: stop() racing a full queue used to drop the worker's
    in-flight produced item on the floor. stop(drain=True) must let the
    hand-off finish and return every undelivered record."""
    import time

    produced = []

    def produce(k):
        produced.append(k)
        return np.full((3,), k, np.int64)

    pf = Prefetcher(produce, depth=1, n=2).start()
    deadline = time.perf_counter() + 5.0
    while len(produced) < 2 and time.perf_counter() < deadline:
        time.sleep(0.01)                # item 0 queued, item 1 stuck in _put
    assert produced == [0, 1]
    drained = pf.stop(drain=True)
    ks = [rec[0] for rec in drained if isinstance(rec, tuple)]
    assert ks == [0, 1]                 # nothing produced was lost
    assert drained[1][1][0] == 1
    assert pf._thread is None


def test_memmap_catalog_splits_rejects_trailing_bytes(tmp_path):
    """PR8 satellite: a catalog file whose size is not a multiple of d*4 was
    silently truncated by the row-count floor-division; it must refuse with
    an error naming the file and the remainder."""
    rows = np.arange(12, dtype=np.float32).reshape(4, 3)
    path = str(tmp_path / "cat.f32")
    MemmapCatalogSplits.write(path, rows)
    with open(path, "ab") as f:
        f.write(b"\x00" * 5)            # torn write: 5 trailing bytes
    with pytest.raises(ValueError, match=r"5 trailing bytes") as ei:
        MemmapCatalogSplits(path, d=3, rows_per_split=2)
    assert "cat.f32" in str(ei.value)
    # the untampered file still loads fine
    ok = str(tmp_path / "ok.f32")
    MemmapCatalogSplits.write(ok, rows)
    assert MemmapCatalogSplits(ok, d=3, rows_per_split=2).n_splits() == 2
