"""Data pipeline determinism + sharding + memmap backend."""
import numpy as np

from repro.data import MemmapTokens, Pipeline, PipelineConfig, SyntheticTokens


def test_synthetic_deterministic():
    a = SyntheticTokens(1000, seed=7).block(100, 4, 16)
    b = SyntheticTokens(1000, seed=7).block(100, 4, 16)
    assert np.array_equal(a, b)
    c = SyntheticTokens(1000, seed=8).block(100, 4, 16)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


def test_host_shards_are_disjoint_and_cover():
    src = SyntheticTokens(50000, seed=0)
    full = Pipeline(src, PipelineConfig(8, 16, host_id=0, n_hosts=1))
    parts = [Pipeline(src, PipelineConfig(8, 16, host_id=h, n_hosts=2))
             for h in range(2)]
    want = full.batch_at(5)
    got = np.concatenate([p.batch_at(5) for p in parts], axis=0)
    assert np.array_equal(want, got)


def test_elastic_replay_same_batches():
    """A rescaled job (different host count) sees the same global batch."""
    src = SyntheticTokens(1234, seed=1)
    g1 = Pipeline(src, PipelineConfig(12, 8, n_hosts=1)).batch_at(3)
    g2 = np.concatenate([
        Pipeline(src, PipelineConfig(12, 8, host_id=h, n_hosts=3)).batch_at(3)
        for h in range(3)], axis=0)
    assert np.array_equal(g1, g2)


def test_prefetch_iterator():
    pipe = Pipeline(SyntheticTokens(100, 0),
                    PipelineConfig(4, 8, prefetch=2)).start()
    it = iter(pipe)
    s0, b0 = next(it)
    s1, b1 = next(it)
    pipe.stop()
    assert s0 == 0 and s1 == 1
    assert b0.shape == (4, 8) and not np.array_equal(b0, b1)
    assert np.array_equal(b0, pipe.batch_at(0))


def test_memmap_roundtrip(tmp_path):
    path = str(tmp_path / "tokens.bin")
    data = np.arange(4 * 32, dtype=np.int32).reshape(4, 32)
    MemmapTokens.write(path, data)
    src = MemmapTokens(path, seq_len=32)
    assert np.array_equal(src.block(1, 2, 32), data[1:3])
    # wraps around
    assert np.array_equal(src.block(3, 2, 32)[1], data[0])
