"""Pallas kernel sweeps: interpret-mode kernel vs pure-jnp oracle over
shapes x dtypes (per assignment: every kernel gets an allclose sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import sky
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize.kernel import dequantize_pallas, quantize_pallas
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref
from repro.kernels.zones_pairs.kernel import (pair_count_masked_pallas,
                                              pair_count_pallas,
                                              pair_hist_masked_pallas,
                                              pair_hist_pallas)
from repro.kernels.zones_pairs.ref import pair_count_ref, pair_hist_ref


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,cols", [(8, 256), (16, 1024), (8, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_sweep(rng, rows, cols, dtype):
    x = (jax.random.normal(rng, (rows, cols), jnp.float32) * 3).astype(dtype)
    q1, s1 = quantize_pallas(x, interpret=True)
    q2, s2 = quantize_ref(x)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    else:
        # bf16 inputs: division order at exact .5 boundaries may differ by 1 LSB
        d = np.abs(np.asarray(q1, np.int32) - np.asarray(q2, np.int32))
        assert d.max() <= 1 and (d > 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    # dequant: kernel vs ref on identical (q, s) must agree exactly; and the
    # roundtrip error stays within the per-block quantization bound
    d1 = dequantize_pallas(q1, s1, interpret=True)
    d2 = dequantize_ref(q1, s1)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
    err = np.abs(np.asarray(d1) - np.asarray(x, np.float32))
    bound = np.repeat(np.asarray(s1), 256, axis=-1) * 0.51 + 1e-6
    assert np.all(err <= bound + np.asarray(s1).max())


# ---------------------------------------------------------------------------
# zones_pairs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,tm,tn", [(256, 256, 256, 256),
                                       (512, 256, 256, 256),
                                       (512, 512, 128, 256)])
@pytest.mark.parametrize("radius", [0.02, 0.1])
def test_pair_count_sweep(m, n, tm, tn, radius):
    a = jnp.asarray(sky.make_catalog(m, 1))
    b = jnp.asarray(sky.make_catalog(n, 2))
    cm = float(np.cos(radius))
    got = pair_count_pallas(a, b, cm, tm=tm, tn=tn, interpret=True)
    want = pair_count_ref(a, b, cm)
    assert int(got) == int(want)


def test_pair_count_exclude_self():
    a = jnp.asarray(sky.make_catalog(256, 3))
    cm = float(np.cos(0.05))
    got = pair_count_pallas(a, a, cm, exclude_self=True, tm=128, tn=128,
                            interpret=True)
    want = pair_count_ref(a, a, cm, exclude_self=True)
    assert int(got) == int(want)


@pytest.mark.parametrize("nbins", [4, 16, 60])
def test_pair_hist_sweep(nbins):
    a = jnp.asarray(sky.make_catalog(256, 4))
    b = jnp.asarray(sky.make_catalog(512, 5))
    edges = jnp.asarray(np.cos(np.linspace(0.01, 0.2, nbins)), jnp.float32)
    got = pair_hist_pallas(a, b, edges, tm=256, tn=256, interpret=True)
    want = pair_hist_ref(a, b, edges)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# zones_pairs: masked-batched variants (leading partition axis + n_a/n_b
# masking) — Pallas interpret-mode and the z-banded blocked reduce, both vs
# a per-partition loop over the 2D reference on the *real* (unpadded) rows.
# ---------------------------------------------------------------------------

# ragged per-partition real counts, including zero-size partitions, a
# full-capacity partition, a single-partition "tier", and an ALL-padding
# batch (every count zero — what a phantom-only mesh shard hands the
# kernels; they must return exactly zero, not NaN or garbage)
MASKED_CASES = [
    # (P, C1, C2, n_owned, n_bucket)
    (4, 128, 256, (0, 128, 64, 1), (0, 256, 100, 3)),
    (3, 64, 64, (64, 64, 64), (64, 64, 64)),          # single size class
    (1, 256, 128, (200,), (90,)),                      # single partition
    (5, 64, 128, (0, 0, 10, 64, 33), (0, 5, 0, 128, 77)),
    (3, 64, 64, (0, 0, 0), (0, 0, 0)),                 # all-padding shard
]


def _masked_case(P, C1, C2, n_o, n_b, seed=0):
    a = jnp.asarray(np.stack([sky.make_catalog(C1, seed + p)
                              for p in range(P)]))
    b = jnp.asarray(np.stack([sky.make_catalog(C2, 100 + seed + p)
                              for p in range(P)]))
    return a, b, jnp.asarray(n_o, jnp.int32), jnp.asarray(n_b, jnp.int32)


def _loop_count(a, b, n_o, n_b, cmin):
    return sum(int(pair_count_ref(a[p, :n_o[p]], b[p, :n_b[p]], cmin))
               for p in range(a.shape[0]))


def _loop_hist(a, b, n_o, n_b, edges):
    out = np.zeros(edges.shape[0], np.int64)
    for p in range(a.shape[0]):
        out += np.asarray(pair_hist_ref(a[p, :n_o[p]], b[p, :n_b[p]], edges),
                          np.int64)
    return out


@pytest.mark.parametrize("P,C1,C2,n_o,n_b", MASKED_CASES)
@pytest.mark.parametrize("radius", [0.05, 0.3])
def test_pair_count_masked_ragged(P, C1, C2, n_o, n_b, radius):
    from repro.kernels.zones_pairs.blocked import pair_count_blocked
    from repro.kernels.zones_pairs.ref import pair_count_masked_ref
    a, b, no, nb = _masked_case(P, C1, C2, n_o, n_b)
    cmin = float(np.cos(radius))
    want = _loop_count(a, b, list(n_o), list(n_b), cmin)
    got_pl = pair_count_masked_pallas(a, b, no, nb, cmin, tm=64, tn=64,
                                      interpret=True)
    got_ref = pair_count_masked_ref(a, b, no, nb, cmin)
    got_blk = pair_count_blocked(a, b, no, nb, cmin)
    assert int(got_pl) == want and int(got_ref) == want, (got_pl, want)
    assert int(got_blk) == want, (got_blk, want)


@pytest.mark.parametrize("P,C1,C2,n_o,n_b", MASKED_CASES)
@pytest.mark.parametrize("nbins", [3, 17])
def test_pair_hist_masked_ragged(P, C1, C2, n_o, n_b, nbins):
    from repro.kernels.zones_pairs.blocked import pair_hist_blocked
    from repro.kernels.zones_pairs.ref import pair_hist_masked_ref
    a, b, no, nb = _masked_case(P, C1, C2, n_o, n_b, seed=7)
    edges = jnp.asarray(np.cos(np.linspace(0.02, 0.4, nbins)), jnp.float32)
    want = _loop_hist(a, b, list(n_o), list(n_b), edges)
    got_pl = pair_hist_masked_pallas(a, b, no, nb, edges, tm=64, tn=64,
                                     interpret=True)
    got_ref = pair_hist_masked_ref(a, b, no, nb, edges)
    got_blk = pair_hist_blocked(a, b, no, nb, edges)
    np.testing.assert_array_equal(np.asarray(got_pl, np.int64), want)
    np.testing.assert_array_equal(np.asarray(got_ref, np.int64), want)
    np.testing.assert_array_equal(np.asarray(got_blk, np.int64), want)


def test_blocked_prunes_but_counts_exactly():
    """The z-banded blocked reduce must skip tile pairs (on a z-sorted
    catalog spanning the sphere) yet return exactly the dense masked
    count."""
    from repro.kernels.zones_pairs import blocked
    from repro.kernels.zones_pairs.ref import pair_count_masked_ref
    xyz = sky.make_catalog(2048, 3)
    xyz = xyz[np.argsort(xyz[:, 2])]        # z-sorted -> tight tile ranges
    a = jnp.asarray(xyz[None])               # one big partition
    no = jnp.asarray([2048], jnp.int32)
    cmin = float(np.cos(0.05))
    planned = blocked._plan_blocks(a, a, no, no, cmin)
    n_tiles = (2048 // blocked.TM)
    assert len(planned[0]) < n_tiles * n_tiles          # pruning happened
    got = blocked.pair_count_blocked(a, a, no, no, cmin)
    want = pair_count_masked_ref(a, a, no, no, cmin)
    assert int(got) == int(want)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,Kv,dh,window,cap", [
    (256, 4, 4, 64, 0, 0.0),
    (256, 4, 2, 64, 0, 0.0),         # GQA
    (256, 4, 1, 32, 64, 0.0),        # MQA + window
    (128, 8, 4, 64, 0, 50.0),        # softcap (gemma2)
    (192, 2, 2, 64, 0, 0.0),         # non-multiple of block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(rng, S, H, Kv, dh, window, cap, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    q = (jax.random.normal(k1, (2, S, H, dh)) * 0.5).astype(dtype)
    k = (jax.random.normal(k2, (2, S, Kv, dh)) * 0.5).astype(dtype)
    v = (jax.random.normal(k3, (2, S, Kv, dh)) * 0.5).astype(dtype)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 softcap=cap, bq=64, bk=64, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=window, softcap=cap)
    atol = 2e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_custom_vjp_backward(rng):
    from repro.kernels.flash_attention.ops import flash_attention
    q = jax.random.normal(rng, (1, 64, 2, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (1, 64, 2, 16))
    g1 = jax.grad(lambda q: jnp.sum(flash_attention(q, k, v, True, 0, 0.0,
                                                    None, False)))(q)
    g2 = jax.grad(lambda q: jnp.sum(attention_ref(q, k, v, causal=True)))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)
