"""End-to-end training loop: runs, checkpoints, resumes, recovers from failure."""
import os

import jax
import numpy as np
import pytest

from repro.configs import RunConfig, get_arch
from repro.launch.train import train


# Resuming a donated train step from a restored checkpoint segfaults jaxlib
# 0.4.x on CPU; the resume tests need current jax (they run in CI).
_OLD_JAX = not hasattr(jax, "shard_map")


def _rc(steps):
    return RunConfig(remat="none", steps=steps, warmup_steps=2,
                     learning_rate=1e-3)


def test_loss_decreases_on_learnable_data(cpu_mesh, tmp_path):
    """Deterministic memorization check: repeated steps on one fixed batch must
    drive the loss down (hash-random streams only admit unigram learning, which
    is too noisy for a strict monotonicity assertion)."""
    import jax
    import jax.numpy as jnp
    from repro.parallel.sharding import use_mesh
    from repro.training.state import init_state
    from repro.training.step import make_train_step
    cfg = get_arch("tinyllama-1.1b").reduced()
    rc = _rc(20)
    step_fn, _, _, rules = make_train_step(cfg, rc, cpu_mesh)
    with use_mesh(cpu_mesh, rules):
        state = init_state(cfg, rc, jax.random.PRNGKey(0), cpu_mesh)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab)}
    losses = []
    for _ in range(20):
        state, mets = step_fn(state, batch)
        losses.append(float(mets["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


@pytest.mark.skipif(_OLD_JAX, reason="ckpt-resume segfaults jaxlib 0.4.x CPU")
def test_checkpoint_resume_matches_uninterrupted(cpu_mesh, tmp_path):
    cfg = get_arch("tinyllama-1.1b").reduced()
    d1 = str(tmp_path / "a")
    d2 = str(tmp_path / "b")
    # uninterrupted 8 steps
    _, losses_full = train(cfg, _rc(8), batch=4, seq=32, steps=8,
                           ckpt_dir=d1, ckpt_every=100, mesh=cpu_mesh,
                           log_every=1000)
    # 4 steps, checkpoint, resume 4 more
    train(cfg, _rc(8), batch=4, seq=32, steps=4, ckpt_dir=d2, ckpt_every=4,
          mesh=cpu_mesh, log_every=1000)
    _, losses_resumed = train(cfg, _rc(8), batch=4, seq=32, steps=4,
                              ckpt_dir=d2, ckpt_every=100, mesh=cpu_mesh,
                              log_every=1000)
    np.testing.assert_allclose(losses_full[4:], losses_resumed, rtol=1e-4)


@pytest.mark.skipif(_OLD_JAX, reason="ckpt-resume segfaults jaxlib 0.4.x CPU")
def test_failure_injection_and_restart(cpu_mesh, tmp_path):
    cfg = get_arch("tinyllama-1.1b").reduced()
    d = str(tmp_path / "ckpt")
    with pytest.raises(RuntimeError, match="injected failure"):
        train(cfg, _rc(10), batch=4, seq=32, steps=10, ckpt_dir=d,
              ckpt_every=3, inject_failure_at=7, mesh=cpu_mesh, log_every=1000)
    # restart resumes from the last checkpoint and completes
    state, losses = train(cfg, _rc(10), batch=4, seq=32, steps=4,
                          ckpt_dir=d, ckpt_every=100, mesh=cpu_mesh,
                          log_every=1000)
    assert len(losses) == 4 and all(np.isfinite(l) for l in losses)


def test_serve_engine_completes_requests(cpu_mesh):
    from repro.models import model as mdl
    from repro.parallel.sharding import make_rules, use_mesh
    from repro.serving.engine import Request, ServeEngine
    cfg = get_arch("tinyllama-1.1b").reduced()
    rc = RunConfig(remat="none")
    with use_mesh(cpu_mesh, make_rules(cpu_mesh)):
        params, biases = mdl.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, rc, params, biases, cpu_mesh, slots=2, max_len=64)
    assert not eng.closed
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=5))
    eng.run(max_steps=60)
    assert len(eng.queue) == 0
    assert all(s is None for s in eng.active)
    # drained -> closed: a late submission would never be served, so it
    # must be rejected instead of silently enqueued into a dead engine
    assert eng.closed
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(Request(rid=9, prompt=[1], max_new=2))
