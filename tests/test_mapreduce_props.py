"""Property-based invariants for the MapReduce stack (hypothesis).

The parity surface the mesh-sharded device engine stands on, stated as
properties over random catalogs, skewed zone distributions, and vocab
sizes instead of hand-picked examples:

1. codec contracts — exact codecs round-trip BIT-identically (host and
   device transforms), lossy codecs stay inside ``error_bound``, and the
   static ``nbytes`` formula always matches the real payload;
2. partitioner coverage — for every within-radius pair (i, j), each
   endpoint's zone bucket contains the other endpoint (owned or border
   replica), under both the host ``replicas`` hook and the device
   ``bucket_entries_device`` stream (the ``REPLICA_EPS`` margin makes the
   device set a safe superset, never a subset);
3. engine parity — ``engine="device"`` output is bit-identical to
   ``engine="host"`` for search, stats, and wordcount with exact codecs,
   under both shuffle index paths;
4. streaming parity — the split-streaming executor over RANDOM split
   boundaries (including 1 split and n-splits-of-1) is bit-identical to the
   monolithic run for search/stats/wordcount with exact and int16 codecs,
   and map-side combine (combiner on vs off) changes nothing for monoid
   reducers. The same properties re-run on an 8-device mesh in
   ``md_check.py mapreduce-streaming`` (fixed cases, subprocess);
5. service batching determinism — ANY partition of a request set into
   micro-batches through the MR query service's resident catalog returns
   bit-identical per-request results to single-request execution
   (coalescing and fused batched reduces change scheduling, never
   results). Mesh variant: ``md_check.py mapreduce-service``.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.data import ArraySplits, sky
from repro.mapreduce import (ZonePartitioner, available_codecs, get_codec,
                             neighbor_search_job, neighbor_statistics_job,
                             run_job, run_job_streaming, run_jobs,
                             run_jobs_streaming, token_histogram,
                             token_histogram_job)
from repro.mapreduce import job as job_mod

settings.register_profile("ci", deadline=None, max_examples=10,
                          derandomize=True)
settings.load_profile("ci")


def _catalog(n, seed, clump):
    """Random unit catalog; ``clump`` piles half the points into one tiny
    dec band so the tier planner sees real skew."""
    xyz = sky.make_catalog(max(n, 1), seed)[:n]
    if clump and n >= 8:
        rng = np.random.default_rng(seed + 1)
        k = n // 2
        xyz = xyz.copy()
        xyz[:k] = xyz[k:k + 1] + rng.normal(0, 1e-3, (k, 3))
        xyz /= np.linalg.norm(xyz, axis=1, keepdims=True)
    return xyz.astype(np.float32)


# ---------------------------------------------------------------------------
# 1. codec contracts
# ---------------------------------------------------------------------------

@given(name=st.sampled_from(sorted(available_codecs())),
       n=st.integers(1, 2000), d=st.integers(1, 4), seed=st.integers(0, 99))
def test_codec_roundtrip_and_accounting(name, n, d, seed):
    codec = get_codec(name)
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, (n, d)).astype(np.float32)
    back = codec.roundtrip(x)
    if codec.exact:
        assert np.array_equal(back, x)            # bit-identical, no NaN outs
    else:
        assert np.max(np.abs(back - x)) <= codec.error_bound(x) + 1e-7
    enc = codec.encode(x)
    assert enc.wire_bytes == codec.nbytes(x.size)
    assert sum(a.nbytes for a in enc.arrays) == enc.wire_bytes


@given(n=st.integers(1, 500), d=st.integers(1, 4), seed=st.integers(0, 99))
def test_exact_codec_device_transforms_bit_match_host(n, d, seed):
    """identity/int16 device encode/decode == the host wire trip, bitwise —
    the contract that makes device==host engine parity possible at all."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, (n, d)).astype(np.float32)
    for name in ("identity", "int16"):
        codec = get_codec(name)
        dev = np.asarray(codec.decode_device(*codec.encode_device(
            jnp.asarray(x))))
        assert np.array_equal(dev, codec.roundtrip(x)), name


# ---------------------------------------------------------------------------
# 2. partitioner assign/replicas coverage under REPLICA_EPS
# ---------------------------------------------------------------------------

@given(n=st.integers(2, 180), seed=st.integers(0, 99),
       radius=st.floats(0.02, 0.4), clump=st.booleans())
def test_zone_buckets_cover_every_within_radius_pair(n, seed, radius, clump):
    """For every pair with angular distance <= radius (f64 oracle), each
    endpoint's zone bucket must contain the other endpoint. Holds for the
    host ``replicas`` hook and for the device entry stream, whose valid set
    must additionally be a superset of the host replica set (REPLICA_EPS
    margins may only ADD copies, never drop one)."""
    import jax.numpy as jnp
    xyz = _catalog(n, seed, clump)
    part = ZonePartitioner(radius)
    P = part.n_partitions(xyz)
    keys = part.assign(xyz)
    assert keys.min() >= 0 and keys.max() < P

    buckets = [set(np.flatnonzero(keys == k)) for k in range(P)]
    host_pairs = set()
    for dest, idx in part.replicas(xyz, keys, P):
        assert 0 <= dest < P
        buckets[dest].update(int(i) for i in idx)
        host_pairs.update((int(dest), int(i)) for i in idx)

    dots = np.clip(xyz.astype(np.float64) @ xyz.astype(np.float64).T, -1, 1)
    ii, jj = np.nonzero(dots >= np.cos(radius))
    for i, j in zip(ii, jj):
        assert j in buckets[keys[i]], (i, j, keys[i], keys[j])

    dest_d, src_d, valid_d = part.bucket_entries_device(
        jnp.asarray(xyz), jnp.asarray(keys), P)
    dev_pairs = {(int(d), int(s)) for d, s, v in
                 zip(np.asarray(dest_d), np.asarray(src_d),
                     np.asarray(valid_d)) if v}
    own_pairs = {(int(k), int(i)) for i, k in enumerate(keys)}
    assert dev_pairs >= own_pairs
    assert dev_pairs >= host_pairs        # device may replicate MORE, not less


# ---------------------------------------------------------------------------
# 3. device == host bit parity across engines
# ---------------------------------------------------------------------------

@given(n=st.sampled_from([0, 1, 37, 160, 400]), seed=st.integers(0, 30),
       radius=st.sampled_from([0.06, 0.12, 0.3]),
       codec=st.sampled_from(["identity", "int16"]), clump=st.booleans(),
       index_impl=st.sampled_from(["host", "jnp"]))
def test_search_and_stats_device_host_parity(n, seed, radius, codec, clump,
                                             index_impl):
    xyz = _catalog(n, seed, clump)
    edges = np.linspace(radius / 3, radius, 4)
    old = job_mod.SHUFFLE_INDEX_IMPL
    job_mod.SHUFFLE_INDEX_IMPL = index_impl
    try:
        sjob = neighbor_search_job(radius, codec=codec, tile=64)
        hjob = neighbor_statistics_job(edges / sky.ARCSEC, codec=codec,
                                       tile=64)
        assert (run_job(sjob, xyz, engine="device").output
                == run_job(sjob, xyz, engine="host").output)
        np.testing.assert_array_equal(
            run_job(hjob, xyz, engine="device").output,
            run_job(hjob, xyz, engine="host").output)
    finally:
        job_mod.SHUFFLE_INDEX_IMPL = old


@given(n=st.integers(0, 3000), vocab=st.integers(2, 1000),
       n_parts=st.sampled_from([3, 8, 16]), seed=st.integers(0, 99),
       codec=st.sampled_from(["identity", "int16"]), zipf=st.booleans())
def test_wordcount_device_host_parity(n, vocab, n_parts, seed, codec, zipf):
    rng = np.random.default_rng(seed)
    if zipf:   # skewed token distribution (a few very hot tokens)
        toks = np.minimum(rng.zipf(1.6, size=n) - 1, vocab - 1)
    else:
        toks = rng.integers(0, vocab, n)
    dev = token_histogram(toks, vocab, n_partitions=n_parts, tile=64,
                          codec=codec, engine="device").output
    host = token_histogram(toks, vocab, n_partitions=n_parts, tile=64,
                           codec=codec, engine="host").output
    np.testing.assert_array_equal(dev, host)
    np.testing.assert_array_equal(dev, np.bincount(toks, minlength=vocab))


# ---------------------------------------------------------------------------
# 4. split-streaming executor == monolithic run (random split boundaries)
# ---------------------------------------------------------------------------

def _boundaries(n, seed, n_cuts):
    """Random split boundaries in [0, n] — duplicates allowed, so empty
    splits (and the 1-split / n-splits-of-1 extremes) occur naturally."""
    if n_cuts >= n:                    # n-splits-of-1
        return list(range(1, n))
    rng = np.random.default_rng(seed ^ 0x5EED)
    return sorted(int(b) for b in rng.integers(0, n + 1, n_cuts))


@given(n=st.sampled_from([1, 37, 160, 400]), seed=st.integers(0, 30),
       radius=st.sampled_from([0.06, 0.12, 0.3]),
       codec=st.sampled_from(["identity", "int16"]), clump=st.booleans(),
       n_cuts=st.sampled_from([0, 1, 3, 40]))
def test_streaming_matches_monolithic_search_stats(n, seed, radius, codec,
                                                   clump, n_cuts):
    """Pair jobs have no valid map-side combine, so streaming accumulates
    wire-dtype splits and reduces once — the result must be BIT-identical
    to the monolithic run for any split boundaries, exact or int16 codec
    (bucket contents are equal multisets; reductions are integer sums)."""
    xyz = _catalog(n, seed, clump)
    src = ArraySplits(xyz, boundaries=_boundaries(n, seed, n_cuts))
    edges = np.linspace(radius / 3, radius, 4)
    part = ZonePartitioner(radius)
    jobs = [neighbor_search_job(radius, partitioner=part, codec=codec,
                                tile=64),
            neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                    codec=codec, tile=64)]
    mono = run_jobs(jobs, xyz)
    stream = run_jobs_streaming(jobs, src)
    assert stream[0].stats.n_splits == src.n_splits()
    assert stream[0].stats.combiner == ""      # pair kernels: no combiner
    assert stream[0].output == mono[0].output
    np.testing.assert_array_equal(stream[1].output, mono[1].output)


@given(n=st.integers(0, 2000), vocab=st.sampled_from([7, 100, 900]),
       seed=st.integers(0, 99), codec=st.sampled_from(["identity", "int16"]),
       n_cuts=st.sampled_from([0, 2, 5, 40]))
def test_streaming_wordcount_and_combiner_equality(n, vocab, seed, codec,
                                                   n_cuts):
    """Wordcount streams bit-identically to the monolithic run, and —
    being a commutative-monoid reducer — with the map-side combiner forced
    on OR off (combiner pre-aggregation must change bytes, never counts)."""
    toks = np.random.default_rng(seed).integers(0, vocab, n)
    items = toks.astype(np.float32).reshape(-1, 1)
    src = ArraySplits(items, boundaries=_boundaries(n, seed, n_cuts))
    job = token_histogram_job(vocab, codec=codec, tile=64)
    want = run_job(job, items).output
    no_comb = run_job_streaming(job, src, combiner=None)
    np.testing.assert_array_equal(no_comb.output, want)
    auto = run_job_streaming(job, src)         # derives combiner iff exact
    np.testing.assert_array_equal(auto.output, want)
    if get_codec(job.codec).exact:
        assert auto.stats.combiner == "token_count"
        comb = run_job_streaming(job, src,
                                 combiner=job.reducer.combiner())
        np.testing.assert_array_equal(comb.output, want)
    np.testing.assert_array_equal(
        want, np.bincount(toks, minlength=vocab))

# ---------------------------------------------------------------------------
# 5. query-service micro-batching == single-request execution
# ---------------------------------------------------------------------------

@given(n=st.sampled_from([1, 60, 200]), seed=st.integers(0, 30),
       codec=st.sampled_from(["identity", "int16"]), clump=st.booleans(),
       picks=st.lists(st.integers(0, 3), min_size=1, max_size=10),
       data=st.data())
def test_service_any_microbatch_partition_matches_single(n, seed, codec,
                                                         clump, picks, data):
    """The resident catalog's shuffle IS the shuffle run_job would do, and
    coalesced fused reduces are the run_jobs batching — so any partition of
    a request stream into micro-batches (drawn at random, down to
    one-request batches) must return bit-identical per-request results to
    fresh single-request runs."""
    from repro.serving.mr_service import MRQueryService
    xyz = _catalog(n, seed, clump)
    radius = 0.12
    part = ZonePartitioner(radius)
    edges = np.linspace(radius / 4, radius, 4)
    menu = [neighbor_search_job(radius, partitioner=part, codec=codec,
                                tile=64),
            neighbor_search_job(radius / 2, partitioner=part, codec=codec,
                                tile=64),
            neighbor_statistics_job(edges / sky.ARCSEC, partitioner=part,
                                    codec=codec, tile=64),
            neighbor_statistics_job(edges[:2] / sky.ARCSEC, partitioner=part,
                                    codec=codec, tile=64)]
    stream = [menu[p] for p in picks]
    singles = [run_job(j, xyz).output for j in stream]
    sizes = []
    left = len(stream)
    while left:                         # random partition of the queue
        k = data.draw(st.integers(1, left))
        sizes.append(k)
        left -= k
    svc = MRQueryService(max_batch=len(stream))
    svc.load_catalog("sky", xyz, part, codec=codec, tile=64)
    reqs = [svc.submit(j, catalog="sky") for j in stream]
    svc.run_pending(batch_sizes=sizes)
    assert [b["size"] for b in svc.batches] == sizes
    for r, want in zip(reqs, singles):
        np.testing.assert_array_equal(r.output, want)
    svc.close()
