"""Observability layer: tracing, energy metering, metrics.

Three contracts, tested in isolation and threaded through the runtime:

- ``obs.trace``: spans nest, inherit ambient ids, export as valid Chrome
  trace-event JSON, and — the load-bearing invariant — every opened span
  CLOSES even when the traced code dies mid-stage (a chaos-killed lane),
  so ``open_spans == 0`` after a crashy run and the export still parses.
- ``obs.energy``: the modeled meter fills the ``StageStats`` energy
  fields deterministically (host profile != device profile), measured
  meters (RAPL) unwrap counter wraparound and degrade to unavailable
  instead of raising, and ``merge_from`` accumulates joules like any
  other per-stage cost.
- ``obs.metrics``: counters / gauges / histograms aggregate and export,
  and the MR query service feeds them live.

Plus the ``latency_summary`` degenerate-span edges (a single request
must not report ~1e9 qps) fixed alongside this layer.
"""
import json
import threading

import pytest

from repro.data import sky
from repro.data.pipeline import ArraySplits
from repro.ft import LaneChaos
from repro.mapreduce import (RequestStats, ZonePartitioner, latency_summary,
                             neighbor_search_job, run_job, run_job_streaming)
from repro.mapreduce.instrumentation import StageStats
from repro.obs import (ATOM_HOST, BLADE_DEVICE, MetricsRegistry, ModeledMeter,
                       NullTracer, NvmlMeter, RaplMeter, Tracer, get_meter,
                       get_tracer, pick_meter, use_meter, use_tracer)
from repro.serving import MRQueryService

RADIUS = 0.02


def _catalog(n=3000, seed=0):
    return sky.make_catalog(n, seed=seed)


# ---------------------------------------------------------------------------
# latency_summary edges (the degenerate-span qps fix)
# ---------------------------------------------------------------------------

def test_latency_summary_empty_stream():
    s = latency_summary([])
    assert s["n"] == 0 and s["qps"] == 0.0 and s["span_s"] == 0.0
    assert s["p50_ms"] == 0.0 and s["mean_batch"] == 0.0


def test_latency_summary_single_request_reports_span_not_blowup():
    r = RequestStats(rid=0, t_submit_s=10.0, latency_s=0.25, batch_size=1)
    s = latency_summary([r])
    assert s["n"] == 1
    assert s["span_s"] == pytest.approx(0.25)
    assert s["qps"] == pytest.approx(1 / 0.25)


def test_latency_summary_identical_zero_latency_submits_clamps_qps():
    # all requests at the same instant with zero latency: span carries no
    # throughput information — qps must clamp to 0, not divide by a floor
    reqs = [RequestStats(rid=i, t_submit_s=5.0, latency_s=0.0, batch_size=2)
            for i in range(4)]
    s = latency_summary(reqs)
    assert s["n"] == 4 and s["span_s"] == 0.0
    assert s["qps"] == 0.0
    assert s["mean_batch"] == 2.0


def test_latency_summary_normal_stream():
    reqs = [RequestStats(rid=i, t_submit_s=float(i), latency_s=0.5,
                         queue_wait_s=0.1, batch_size=3) for i in range(5)]
    s = latency_summary(reqs)
    assert s["span_s"] == pytest.approx(4.5)
    assert s["qps"] == pytest.approx(5 / 4.5)
    assert s["p50_ms"] == pytest.approx(500.0)
    assert s["wait_p50_ms"] == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# StageStats energy accumulation
# ---------------------------------------------------------------------------

def test_merge_from_sums_energy_fields():
    a = StageStats(job="x", engine="host", energy_source="modeled:atom-host",
                   energy_j=3.0, map_energy_j=1.0, shuffle_energy_j=0.5,
                   reduce_energy_j=1.5, n_items=100)
    b = StageStats(job="x", engine="host", energy_source="modeled:atom-host",
                   energy_j=2.0, map_energy_j=0.5, shuffle_energy_j=0.5,
                   reduce_energy_j=0.25, fetch_energy_j=0.25,
                   combine_energy_j=0.25, spill_energy_j=0.25, n_items=100)
    a.merge_from(b)
    assert a.energy_j == pytest.approx(5.0)
    assert a.map_energy_j == pytest.approx(1.5)
    assert a.shuffle_energy_j == pytest.approx(1.0)
    assert a.reduce_energy_j == pytest.approx(1.75)
    assert a.fetch_energy_j == pytest.approx(0.25)
    assert a.combine_energy_j == pytest.approx(0.25)
    assert a.spill_energy_j == pytest.approx(0.25)
    assert a.energy_source == "modeled:atom-host"
    assert a.rows_per_joule == pytest.approx(200 / 5.0)


def test_rows_per_joule_zero_when_unmetered():
    assert StageStats(n_items=100).rows_per_joule == 0.0


# ---------------------------------------------------------------------------
# Tracer unit behaviour
# ---------------------------------------------------------------------------

def test_tracer_nesting_ids_and_export_shape():
    tr = Tracer()
    with tr.ids(lane=2, split=7):
        with tr.span("outer", cat="stage"):
            with tr.span("inner", cat="io", attempt=1):
                pass
    tr.instant("mark", split=7)
    assert tr.open_spans == 0
    doc = json.loads(tr.export_json())
    assert doc["displayTimeUnit"] == "ms"
    evs = {e["name"]: e for e in doc["traceEvents"]}
    assert set(evs) == {"outer", "inner", "mark"}
    inner = evs["inner"]
    # complete event shape + ambient ids inherited, per-span ids merged
    assert inner["ph"] == "X" and inner["dur"] >= 0.0
    assert {"ts", "pid", "tid", "args"} <= set(inner)
    assert inner["args"] == {"lane": 2, "split": 7, "attempt": 1}
    assert evs["mark"]["ph"] == "i" and evs["mark"]["s"] == "t"
    # inner closed first: events append at close time
    assert doc["traceEvents"].index(inner) < \
        doc["traceEvents"].index(evs["outer"])


def test_tracer_span_closes_on_exception():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("doomed"):
            raise RuntimeError("mid-stage death")
    assert tr.open_spans == 0
    assert tr.events[0]["name"] == "doomed"


def test_tracer_record_retroactive_and_summary():
    tr = Tracer()
    t0 = tr.now()
    tr.record("fetch-wait", t0, t0 + 0.001, cat="io", split=3)
    assert tr.events[0]["dur"] == pytest.approx(1000.0, rel=0.01)
    # negative interval clamps to zero duration, never a negative one
    tr.record("clock-skew", t0 + 1.0, t0)
    assert tr.events[1]["dur"] == 0.0
    text = tr.summary()
    assert "fetch-wait" in text and "count" in text


def test_tracer_threads_keep_separate_ambient_ids():
    tr = Tracer()
    errs = []

    def worker(lane):
        try:
            with tr.ids(lane=lane):
                for _ in range(50):
                    with tr.span("w"):
                        pass
        except Exception as e:          # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert not errs and tr.open_spans == 0
    assert len(tr.events) == 200
    for ev in tr.events:
        # ambient ids must come from the recording thread's own stack
        assert ev["args"]["lane"] in range(4)


def test_null_tracer_is_reentrant_noop():
    tr = NullTracer()
    with tr.span("a"), tr.ids(x=1), tr.span("b"):
        tr.instant("c")
        tr.record("d", 0.0, 1.0)
    assert tr.events == () and tr.open_spans == 0 and not tr.enabled
    assert isinstance(get_tracer(), NullTracer)  # module default stays null


# ---------------------------------------------------------------------------
# Tracing threaded through the runtime — and under chaos
# ---------------------------------------------------------------------------

def test_streaming_run_traces_stages_and_exports_valid_json():
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    with use_tracer(Tracer()) as tr:
        res = run_job_streaming(job, ArraySplits(xyz, n_splits=6), n_lanes=3,
                                prefetch=2)
    assert res.output == want
    assert tr.open_spans == 0
    doc = json.loads(tr.export_json())
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"map", "shuffle", "reduce", "fetch-wait", "lane-exec",
            "job"} <= names
    lane_ev = next(e for e in doc["traceEvents"] if e["name"] == "lane-exec")
    assert "lane" in lane_ev["args"] and "split" in lane_ev["args"]


def test_chaos_killed_lane_leaves_no_open_spans():
    """A lane killed mid-split must not leak spans: the span context
    closes in ``finally``, the retry/lane accounting still records, and
    the export stays valid Chrome trace JSON."""
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    want = run_job(job, xyz).output
    chaos = LaneChaos(kills=[(0, 1)])
    with use_tracer(Tracer()) as tr:
        res = run_job_streaming(job, ArraySplits(xyz, n_splits=6), n_lanes=3,
                                chaos=chaos)
    assert res.output == want and len(chaos.deaths) == 1
    assert tr.open_spans == 0
    doc = json.loads(tr.export_json())
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "i")
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"map", "shuffle", "reduce", "lane-exec"} <= names


# ---------------------------------------------------------------------------
# Energy meters
# ---------------------------------------------------------------------------

def test_modeled_meter_fills_energy_fields_by_engine():
    xyz = _catalog()
    job = neighbor_search_job(RADIUS, tile=128)
    outs = {}
    with use_meter(ModeledMeter()):
        for engine in ("host", "device"):
            r = run_job(job, xyz, engine=engine)
            outs[engine] = r
            st = r.stats
            assert st.energy_j > 0.0
            assert st.map_energy_j > 0.0 and st.reduce_energy_j > 0.0
            assert st.rows_per_joule > 0.0
            # per-stage charges sum to the total
            parts = (st.map_energy_j + st.shuffle_energy_j
                     + st.reduce_energy_j + st.fetch_energy_j
                     + st.combine_energy_j + st.spill_energy_j)
            assert st.energy_j == pytest.approx(parts)
    assert outs["host"].stats.energy_source == "modeled:atom-host"
    assert outs["device"].stats.energy_source == "modeled:amdahl-blade"
    assert outs["host"].output == outs["device"].output  # metering is free


def test_modeled_meter_charges_class_watts():
    st = StageStats(engine="device", map_wall_s=1.0, shuffle_wall_s=2.0)
    ModeledMeter().attribute(None, st)
    assert st.map_energy_j == pytest.approx(1.0 * BLADE_DEVICE.compute_w)
    assert st.shuffle_energy_j == pytest.approx(2.0 * BLADE_DEVICE.io_w)
    host = StageStats(engine="host", shuffle_wall_s=1.0)
    ModeledMeter().attribute(None, host)
    assert host.shuffle_energy_j == pytest.approx(ATOM_HOST.io_w)
    assert ATOM_HOST.io_w > ATOM_HOST.compute_w      # CPU pays for I/O
    assert BLADE_DEVICE.io_w < BLADE_DEVICE.compute_w


def _fake_rapl(root, uj, max_uj=1000_000.0):
    d = root / "intel-rapl:0"
    d.mkdir(parents=True, exist_ok=True)
    (d / "energy_uj").write_text(f"{uj:.0f}\n")
    (d / "max_energy_range_uj").write_text(f"{max_uj:.0f}\n")
    return d


def test_rapl_meter_reads_delta_and_unwraps(tmp_path):
    d = _fake_rapl(tmp_path, 500_000.0)
    # a subdomain must NOT be summed (double count)
    sub = tmp_path / "intel-rapl:0:0"
    sub.mkdir()
    (sub / "energy_uj").write_text("999\n")
    (sub / "max_energy_range_uj").write_text("1000000\n")
    m = RaplMeter(root=str(tmp_path))
    assert m.available and len(m._domains) == 1
    tok = m.begin()
    (d / "energy_uj").write_text("800000\n")
    assert m.read_joules(tok) == pytest.approx(0.3)      # 300k uJ
    # wraparound: counter restarts below the start value
    tok = m.begin()
    (d / "energy_uj").write_text("100000\n")             # wrapped past 1e6
    assert m.read_joules(tok) == pytest.approx(0.3)      # (1e6-8e5)+1e5
    st = StageStats(engine="host", map_wall_s=0.75, shuffle_wall_s=0.25)
    tok = m.begin()
    (d / "energy_uj").write_text("200000\n")
    m.attribute(tok, st)
    assert st.energy_j == pytest.approx(0.1)
    assert st.map_energy_j == pytest.approx(0.075)       # wall-share split
    assert st.energy_source == "rapl"


def test_rapl_meter_unavailable_degrades(tmp_path):
    m = RaplMeter(root=str(tmp_path / "nope"))
    assert not m.available and m.begin() is None
    st = StageStats(map_wall_s=1.0)
    m.attribute(None, st)                                # no-op, no raise
    assert st.energy_j == 0.0 and st.energy_source == ""


def test_nvml_meter_unavailable_degrades():
    m = NvmlMeter(index=0)
    if m.available:                     # pragma: no cover - GPU runners
        pytest.skip("machine exposes an NVML energy counter")
    assert m.begin() is None
    st = StageStats(map_wall_s=1.0)
    m.attribute(None, st)
    assert st.energy_j == 0.0


def test_pick_meter_resolution():
    assert pick_meter("null").name == "null"
    assert pick_meter("modeled").name == "modeled"
    assert pick_meter("auto").name in ("rapl", "nvml", "modeled")
    assert get_meter().name == "null"   # module default stays null


def test_roofline_balance_watts():
    st = StageStats(job="s", engine="device", reduce_flops=1e9,
                    map_bytes=1e6, reduce_bytes=1e6, shuffle_wire_bytes=1e6)
    terms = st.roofline(chip_w=BLADE_DEVICE.compute_w)
    assert terms.chip_w == BLADE_DEVICE.compute_w
    assert terms.balance_watts() == pytest.approx(
        terms.chips_to_balance() * BLADE_DEVICE.compute_w)
    d = terms.to_dict()
    assert d["chip_w"] == BLADE_DEVICE.compute_w and "balance_watts" in d
    assert st.roofline().balance_watts() == 0.0          # no watts supplied


# ---------------------------------------------------------------------------
# Metrics registry + service feed
# ---------------------------------------------------------------------------

def test_metrics_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.counter("reqs").inc()
    reg.counter("reqs").inc(4)
    reg.gauge("depth").set(3.0)
    reg.gauge("depth").add(-1.0)
    h = reg.histogram("lat_ms")
    for v in range(1, 101):
        h.observe(float(v))
    assert reg.counter("reqs").value == 5
    assert reg.gauge("depth").value == 2.0
    snap = h.snapshot()
    assert snap["count"] == 100 and snap["min"] == 1.0 and snap["max"] == 100.0
    assert snap["p50"] == pytest.approx(50.0, abs=1.0)
    assert snap["p99"] == pytest.approx(99.0, abs=1.0)
    d = json.loads(reg.to_json())
    assert d["counters"]["reqs"] == 5
    text = reg.render_text()
    assert "reqs_total 5" in text and 'quantile="p99"' in text


def test_histogram_window_drops_oldest():
    from repro.obs.metrics import Histogram
    h = Histogram("w", max_samples=10)
    for v in range(100):
        h.observe(float(v))
    snap = h.snapshot()
    assert snap["count"] == 100                 # total observations
    assert snap["min"] == 90.0                  # window keeps the newest
    assert Histogram("empty").snapshot()["count"] == 0


def test_service_feeds_metrics():
    xyz = sky.make_catalog(600, 3)
    part = ZonePartitioner(0.1)
    job = neighbor_search_job(0.1, partitioner=part, codec="int16", tile=64)
    svc = MRQueryService(max_batch=8)
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    reqs = [svc.submit(job, catalog="sky") for _ in range(5)]
    assert svc.metrics.counter("mr_requests").value == 5
    assert svc.metrics.gauge("mr_queue_depth").value == 5.0
    svc.run_pending()
    want = run_job(job, xyz).output
    assert all(r.output == want for r in reqs)
    assert svc.metrics.counter("mr_requests_served").value == 5
    assert svc.metrics.counter("mr_batches").value >= 1
    assert svc.metrics.histogram("mr_latency_ms").count == 5
    assert svc.metrics.gauge("mr_queue_depth").value == 0.0
    assert "mr_latency_ms" in svc.metrics.render_text()


def test_service_batch_spans_under_tracer():
    xyz = sky.make_catalog(600, 3)
    part = ZonePartitioner(0.1)
    job = neighbor_search_job(0.1, partitioner=part, codec="int16", tile=64)
    svc = MRQueryService(max_batch=4, max_wait_s=0.001)
    svc.load_catalog("sky", xyz, part, codec="int16", tile=64)
    with use_tracer(Tracer()) as tr, svc:
        reqs = [svc.submit(job, catalog="sky") for _ in range(6)]
        [r.result(timeout=120) for r in reqs]
    batches = [e for e in tr.events if e["name"] == "service-batch"]
    assert batches and sum(b["args"]["size"] for b in batches) == 6
    assert all("batch" in b["args"] and "rids" in b["args"] for b in batches)
    assert tr.open_spans == 0
    json.loads(tr.export_json())                # parses
