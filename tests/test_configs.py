import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_is_applicable, get_arch
from repro.models.model import count_params_analytic, count_params_total
from repro.models.transformer import plan_layers


def test_registry_has_all_ten():
    assert len(ARCHS) == 10
    for n in ["mamba2-1.3b", "tinyllama-1.1b", "olmo-1b", "gemma2-2b",
              "starcoder2-7b", "musicgen-medium", "recurrentgemma-2b",
              "deepseek-v3-671b", "granite-moe-3b-a800m", "internvl2-2b"]:
        assert n in ARCHS


def test_vocab_padding_divisible():
    for cfg in ARCHS.values():
        assert cfg.vocab_padded % 256 == 0
        assert cfg.vocab_padded >= cfg.vocab


def test_live_cells_count():
    live = sum(cell_is_applicable(c, s)[0]
               for c in ARCHS.values() for s in SHAPES.values())
    assert live == 32            # 40 cells - 8 long_500k skips
    for c in ARCHS.values():
        ok, why = cell_is_applicable(c, SHAPES["long_500k"])
        assert ok == c.sub_quadratic
        if not ok:
            assert "quadratic" in why or "full-attention" in why


@pytest.mark.parametrize("name,total_b,tol", [
    ("tinyllama-1.1b", 1.10, 0.06),
    ("mamba2-1.3b", 1.34, 0.1),
    ("olmo-1b", 1.18, 0.08),
    ("gemma2-2b", 2.61, 0.1),
    ("starcoder2-7b", 7.40, 0.15),
    ("recurrentgemma-2b", 2.68, 0.1),
    ("deepseek-v3-671b", 671.7, 5.0),
])
def test_param_counts_match_published(name, total_b, tol):
    got = count_params_total(get_arch(name)) / 1e9
    assert abs(got - total_b) <= tol, (name, got)


def test_deepseek_active_params():
    act = count_params_analytic(get_arch("deepseek-v3-671b"), active_only=True)
    assert 30e9 < act < 40e9      # published ~37B activated


def test_layer_plans():
    groups, tail = plan_layers(get_arch("deepseek-v3-671b"))
    assert [c for _, c in groups] == [3, 58] and tail is None
    groups, tail = plan_layers(get_arch("gemma2-2b"))
    assert len(groups) == 1 and groups[0][1] == 13 and tail is None
    groups, tail = plan_layers(get_arch("recurrentgemma-2b"))
    assert groups[0][1] == 8 and tail is not None and len(tail) == 2
    groups, tail = plan_layers(get_arch("mamba2-1.3b"))
    assert groups[0][1] == 48


def test_reduced_configs_are_small():
    for cfg in ARCHS.values():
        r = cfg.reduced()
        assert count_params_total(r) < 3e6, cfg.name
        assert r.family == cfg.family and r.pattern == cfg.pattern
